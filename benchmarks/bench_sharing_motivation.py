"""Paper Fig. 1: memory demand and aggregate throughput vs #tasks,
shared backbone vs independent deployment."""
from benchmarks.common import emit, run_mode
from repro.controller.profiles import get_profile


def run_all():
    prof = get_profile("moment-large")
    rows = []
    for n in (1, 5, 10):
        shared = (prof.memory_bytes + prof.instance_overhead_bytes
                  + n * prof.task_memory_bytes) / 1e9
        replicated = n * (prof.memory_bytes + prof.instance_overhead_bytes
                          + prof.task_memory_bytes) / 1e9
        rows.append((f"fig1.memory.shared.n{n}_GB", round(shared * 1e3),
                     round(shared, 2)))
        rows.append((f"fig1.memory.replicated.n{n}_GB", round(replicated * 1e3),
                     round(replicated, 2)))
        for mode in ("fmplex", "be"):
            fin, ok, _ = run_mode(mode, n, rps_per_task=12, horizon=20.0)
            thr = (sum(1 for r in fin if r.finish_time) / 20.0) if ok else 0.0
            rows.append((f"fig1.throughput.{mode}.n{n}_rps",
                         round(thr * 1e3), round(thr, 1)))
    n = 10
    ratio = rows[4][2] / rows[1][2] if rows[1][2] else 0
    print(f"fig1.memory.n10_shared_over_single,{ratio:.2f},paper=1.17x")
    return emit(rows)


if __name__ == "__main__":
    run_all()
