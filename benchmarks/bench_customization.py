"""Paper §7.2.3 / Fig. 11: throughput cost of per-task LoRA customization.
FMplex batches the shared backbone pass and loops adapter sub-batches."""
from benchmarks.common import emit, run_mode


def run_all():
    rows = []
    for n in (2, 4, 6, 8, 10):
        for mode, adapters, tag in (("fmplex", True, "fmplex_lora"),
                                    ("fmplex", False, "fmplex_nolora"),
                                    ("be", False, "be")):
            fin, ok, _ = run_mode(mode, n, rps_per_task=10, horizon=20.0,
                                  adapters=adapters)
            thr = (sum(1 for r in fin if r.finish_time and r.finish_time <= 20)
                   / 20.0) if ok else 0.0
            rows.append((f"fig11.{tag}.n{n}_rps", round(thr * 1e3),
                         round(thr, 1)))
    return emit(rows)


if __name__ == "__main__":
    run_all()
