"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Figure -> module mapping lives in
DESIGN.md §6; §Paper-claims in EXPERIMENTS.md reads this output.

  PYTHONPATH=src python -m benchmarks.run [--only fig12,fig13]
"""
from __future__ import annotations

import argparse
import sys
import time

SUITES = [
    ("fig1", "benchmarks.bench_sharing_motivation"),
    ("fig7_8", "benchmarks.bench_sharing_latency"),
    ("fig9_10", "benchmarks.bench_task_scaling"),
    ("fig11", "benchmarks.bench_customization"),
    ("fig12", "benchmarks.bench_fairness"),
    ("fig13", "benchmarks.bench_noisy_neighbor"),
    ("fig14_15", "benchmarks.bench_cluster"),
    ("fig16", "benchmarks.bench_adaptation"),
    ("fig17", "benchmarks.bench_overhead"),
    ("table3", "benchmarks.bench_microbench"),
    ("kernels", "benchmarks.bench_kernels"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite keys (e.g. fig12,kernels)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    import importlib
    failures = []
    for key, module in SUITES:
        if only and key not in only:
            continue
        print(f"# ==== {key} ({module}) ====", flush=True)
        t0 = time.time()
        try:
            importlib.import_module(module).run_all()
            print(f"# {key} done in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:  # keep the suite running; report at the end
            failures.append((key, repr(e)))
            print(f"# {key} FAILED: {e!r}", flush=True)
    if failures:
        print(f"# {len(failures)} suite(s) failed: {failures}")
        sys.exit(1)
    print("# all benchmark suites completed")


if __name__ == "__main__":
    main()
