"""Paper Fig. 17: FMplex scheduling overhead per request — wall time of the
REAL BFQ code path (arrival tagging + batch formation + completion
bookkeeping), which must stay well under the backbone forward pass."""
import time

from benchmarks.common import emit
from repro.controller.profiles import PAPER_PROFILES
from repro.core.bfq import BFQ
from repro.core.request import Request
from repro.core.vfm import VFM


def run_all():
    rows = []
    for name, prof in PAPER_PROFILES.items():
        sched = BFQ(prof)
        vfms = {f"t{i}": VFM(f"t{i}", weight=1.0 + i % 3) for i in range(8)}
        n = 3000
        t0 = time.perf_counter()
        made = 0
        for i in range(n):
            tid = f"t{i % 8}"
            sched.on_arrival(vfms[tid], Request(tid, i * 1e-4), i * 1e-4)
            if i % prof.b_max == prof.b_max - 1:
                b = sched.next_batch(vfms, i * 1e-4)
                if b:
                    sched.on_complete(b, vfms, i * 1e-4 + prof.l(b.size))
                    made += 1
        dt = time.perf_counter() - t0
        per_req_us = dt / n * 1e6
        rows.append((f"fig17.{name}.sched_overhead", round(per_req_us, 1),
                     f"{per_req_us/ (prof.l(1)*1e6) * 100:.3f}%_of_l1"))
    return emit(rows)


if __name__ == "__main__":
    run_all()
