"""Mixed-load serving benchmark: pooled latency under a concurrent decode
stream — event-loop plane vs the drain-synchronous baseline.

The scenario the paper's headline numbers are about: latency-sensitive pooled
tasks colocated with long generative streams on ONE backbone. Three modes
over the same workload shape:

  * ``pooled_solo``  — the pooled burst alone through the event loop
    (the no-interference floor);
  * ``mixed_loop``   — pooled burst + concurrent 64-step decode streams
    through ``ServeLoop``: BFQ picks per tick between a pooled sub-batch, a
    prefill admission, and one decode chunk, so pooled batches interleave
    BETWEEN chunks and arrivals join the pool mid-flight;
  * ``mixed_drain``  — the same workload through the legacy synchronous
    ``FMplexServer.step`` contract (PR 2 semantics): a generative batch
    drains to completion before the next dispatch, so pooled arrivals wait
    out whole decode streams.

Reported: pooled p50/p99 per mode, decode TTFT/TPOT under the loop, the
drain→loop pooled-p50 improvement ratio, and the steady-state invariants
(zero recompiles across prompt-length buckets + join/leave churn). Results
land under the "mixed" section of ``BENCH_serving.json``.

A second leg serves a HYBRID FM (jamba-style mamba/attention interleave +
MoE) side by side with the attention FM, one engine each, through the same
event loop — the cache-manager plane's acceptance scenario: paged attention
KV beside pooled fixed-size recurrent state, var-len bucketed admission,
exact greedy parity vs a teacher-forced dense reference, zero steady-state
recompiles across churn, and state-slot occupancy gauges. The attention
FM's numbers (and its paged capacity win) are unchanged by the hybrid leg;
results land under the "hybrid" section of ``BENCH_serving.json``.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from common import write_serving_section
from repro.configs import get_config, reduced
from repro.core.physical import PhysicalFM
from repro.core.request import Request
from repro.core.server import FMplexServer
from repro.core.vfm import TaskExtensions
from repro.serving.loadgen import feature_trace
from repro.serving.metrics import (decode_stats, latency_stats, mixed_stats,
                                   page_gauges)

PROMPT_LEN = 16
DECODE_STEPS = 64             # the acceptance scenario: long streams
POOLED_RPS = 60.0
STREAM_EVERY = 0.1            # stream arrival rate per gen task: high enough
HORIZON = 2.0                 # that decode pressure spans the whole horizon
N_GEN_TASKS = 2


def build(seed: int = 0):
    cfg = reduced(get_config("stablelm-1.6b"))
    fm = PhysicalFM(cfg, seed=seed, input_len=PROMPT_LEN, lora_rank=4)
    fm.calibrate(sizes=(1, 2, 4, 8))
    srv = FMplexServer("s0")
    srv.deploy_fm("fm0", fm, scheduler="bfq")
    rng = np.random.RandomState(seed)
    w = rng.randn(cfg.d_model, 4).astype(np.float32) * 0.1
    srv.bind_task("pooled", "fm0", weight=2.0,
                  extensions=TaskExtensions(decoder=lambda f: f @ w))
    for i in range(N_GEN_TASKS):
        fm.adapters.new(f"lora{i}", seed=i)
        srv.bind_task(f"gen{i}", "fm0", weight=1.0,
                      extensions=TaskExtensions(adapter_id=f"lora{i}"))
    # create the pool eagerly with the scenario's shape: a later implicit
    # default-kwargs creation would cap max_new at 32 and clamp the streams.
    # PAGED pool: long-tail decode budgets make stream lengths ragged, so
    # page recycling and the loop's memory-aware admission gate both run
    srv.decode_engine("fm0", num_slots=4, prompt_len=PROMPT_LEN,
                      max_new=DECODE_STEPS, chunk=4, paged=True,
                      page_size=16)
    loop = srv.serve_loop("fm0")
    return srv, cfg, loop


def pooled_trace(cfg, horizon, rps, seed=0, start=0.05):
    """Pooled burst starting AFTER the decode streams are in flight: the
    measured quantity is pooled latency under CONCURRENT decode, so the
    generative plane must already hold the device when these arrive."""
    return feature_trace("pooled", rps, horizon, input_len=PROMPT_LEN,
                         d_model=cfg.d_model, seed=seed, start=start)


def gen_trace(cfg, horizon, steps, seed=0):
    """Decode streams from t=0 (head start over the pooled burst): the
    drain-synchronous baseline grabs these first and drains them to
    completion; the event loop interleaves. Budgets are LONG-TAIL
    (log-uniform in [8, steps], the ``loadgen.long_tail_token_trace`` mix)
    so short streams retire and recycle KV pages under the tail's
    pressure — the workload the paged pool exists for."""
    rng = np.random.RandomState(100 + seed)
    out = []
    for i in range(N_GEN_TASKS):
        t = 0.0
        while t < horizon:
            plen = int(rng.randint(max(1, PROMPT_LEN // 4), PROMPT_LEN + 1))
            new = int(round(np.exp(rng.uniform(np.log(8),
                                               np.log(steps + 1)))))
            new = max(8, min(new, steps))
            out.append(Request(
                f"gen{i}", t,
                payload=rng.randint(0, cfg.vocab_size, plen).astype("int32"),
                tokens=float(plen + new), max_new_tokens=new))
            t += STREAM_EVERY
    return out


def run_loop(loop, trace, max_wall):
    served = loop.run([_clone(r) for r in trace], max_wall=max_wall)
    return served


def run_drain(srv, trace, max_wall):
    """PR 2 semantics: replay arrivals against the wall clock; each step()
    drains its batch (generative members to completion) before returning."""
    trace = sorted([_clone(r) for r in trace], key=lambda r: r.arrival)
    t0 = time.perf_counter()
    i, served = 0, []
    while True:
        now = time.perf_counter()
        if now - t0 > max_wall:
            break
        while i < len(trace) and trace[i].arrival <= now - t0:
            r = trace[i]
            r.arrival = t0 + r.arrival
            srv.on_arrival(r, now)
            i += 1
        batch = srv.step("fm0")
        if batch is not None:
            served += batch.requests
        elif i >= len(trace):
            break
        else:
            time.sleep(2e-4)
    return served


def _clone(r: Request) -> Request:
    return Request(r.task_id, r.arrival, payload=r.payload, tokens=r.tokens,
                   max_new_tokens=r.max_new_tokens)


# ---------------- hybrid leg (cache-manager plane) ----------------

def _reference_tokens(fm, prompt, steps, s_max, bucket=None):
    """Teacher-forced greedy oracle: dense int8 cache, per-token decode —
    the parity bar for the engine's bucketed paged admission on ANY stack.
    ``bucket``: pad the prompt to the engine's admission bucket (true length
    via ``seq_lens``). Pads are invisible to attention, the recurrent scans,
    and MoE routing alike — but the MoE expert CAPACITY is a static function
    of the group size, so the oracle must feed the same bucket the engine
    admits into (capacity drops are a property of the bucketed model math,
    not a serving artifact)."""
    import jax.numpy as jnp

    from repro.models import lm
    cfg = fm.cfg
    ai = jnp.full((1,), fm.adapters.capacity(), jnp.int32)
    cache = lm.init_cache(cfg, 1, s_max, kv_quant=True)
    seq_lens = None
    if bucket is not None and bucket > len(prompt):
        seq_lens = jnp.full((1,), len(prompt), jnp.int32)
        prompt = np.concatenate(
            [prompt, np.zeros((bucket - len(prompt),), np.int32)])
    lg, cache = lm.prefill(fm.params, cfg, tokens=jnp.asarray(prompt[None]),
                           cache=cache, lora=fm.adapters.stacked(),
                           adapter_idx=ai, lora_impl="gather",
                           seq_lens=seq_lens)
    toks = [int(jnp.argmax(lg, -1)[0])]
    for _ in range(steps - 1):
        lg, cache = lm.decode_step(
            fm.params, cfg, tokens=jnp.asarray([toks[-1]], jnp.int32),
            cache=cache, lora=fm.adapters.stacked(), adapter_idx=ai,
            lora_impl="gather")
        toks.append(int(jnp.argmax(lg, -1)[0]))
    return toks


def build_hybrid(seed: int = 0):
    """A hybrid FM (mamba/attention interleave + MoE) on its own server +
    engine + loop: paged arena for the attention sublayer, pooled state
    slots for the mamba sublayers, same event-loop plane as the attention
    FM."""
    cfg = reduced(get_config("jamba-v0.1-52b"))
    fm = PhysicalFM(cfg, seed=seed, input_len=PROMPT_LEN, lora_rank=4)
    fm.calibrate(sizes=(1, 2, 4))
    srv = FMplexServer("s-hyb")
    srv.deploy_fm("fm0", fm, scheduler="bfq")
    rng = np.random.RandomState(seed)
    w = rng.randn(cfg.d_model, 4).astype(np.float32) * 0.1
    srv.bind_task("pooled", "fm0", weight=2.0,
                  extensions=TaskExtensions(decoder=lambda f: f @ w))
    for i in range(N_GEN_TASKS):
        fm.adapters.new(f"lora{i}", seed=i)
        srv.bind_task(f"gen{i}", "fm0", weight=1.0,
                      extensions=TaskExtensions(adapter_id=f"lora{i}"))
    srv.decode_engine("fm0", num_slots=4, prompt_len=PROMPT_LEN,
                      max_new=DECODE_STEPS, chunk=4, paged=True,
                      page_size=16)
    return srv, cfg, srv.serve_loop("fm0")


def run_hybrid(out_path: str = None, smoke: bool = False, attn_out=None):
    """The hybrid acceptance leg: exact greedy parity vs the teacher-forced
    reference over ragged prompt lengths, then mixed pooled + generative
    churn through the loop with ZERO steady-state recompiles, state-slot
    gauges beside the page gauges, and the attention FM's headline numbers
    embedded for the side-by-side read."""
    srv, cfg, loop = build_hybrid()
    eng = srv.decode_engine("fm0")
    fm = srv.fms["fm0"]
    max_wall = 60.0 if smoke else 300.0
    assert eng.state_pool is not None and eng.paged
    # attention-only planes demoted, not crashed: the capability contract
    assert not eng.prefix_sharing and eng.spec_k == 0 and eng.spill is None

    loop.warmup(pooled_task="pooled", gen_task="gen0")

    # exact token parity: the bucketed right-padded paged admission (pads
    # masked out of attention KV AND the recurrent scans) vs exact-length
    # teacher-forced dense decode
    rng = np.random.RandomState(7)
    steps = min(8, DECODE_STEPS)
    for plen in (5, 11, PROMPT_LEN):
        p = rng.randint(0, cfg.vocab_size, plen).astype(np.int32)
        eng.join("parity", p, max_new_tokens=steps, rid=0)
        (d,) = eng.drain()
        ref = _reference_tokens(fm, p, steps, eng.s_max,
                                bucket=eng.bucket_for_prompt(plen))
        assert d.tokens == ref, f"hybrid parity fail at plen={plen}"
    compiles = eng.compile_count() + fm.compile_count()

    pooled = pooled_trace(cfg, HORIZON, POOLED_RPS)
    gen = gen_trace(cfg, HORIZON, DECODE_STEPS)
    loop.ticks.clear()
    mixed = run_loop(loop, pooled + gen, max_wall)
    ms = mixed_stats(mixed, page_samples=loop.page_samples, engine=eng)
    loop_recompiles = eng.compile_count() + fm.compile_count() - compiles
    gauges = eng.state_pool.gauges()

    out = {
        "config": cfg.name,
        "block_pattern": list(cfg.blocks),
        "moe_experts": cfg.num_experts,
        "prompt_len": PROMPT_LEN,
        "decode_steps": DECODE_STEPS,
        "parity_exact_vs_teacher_forced": True,     # asserted above
        "pooled": ms["pooled"],
        "decode": ms["decode"],
        "state_slots": gauges,
        "engine_pages": page_gauges(eng),
        "capabilities": {"prefix_sharing": eng.prefix_sharing,
                         "speculative": eng.spec_k > 0,
                         "spill_resume": eng.spill is not None,
                         "chunked_prefill": eng.chunked_prefill},
        "steady_state_recompiles_mixed_churn": loop_recompiles,
        "ticks": dict(loop.ticks),
    }
    if attn_out is not None:                        # side-by-side read
        out["attention_fm"] = {
            "config": attn_out["config"],
            "decode": attn_out["mixed_loop"]["decode"],
            "engine_pages": attn_out["engine_pages"],
            "pooled_p50_improvement_drain_over_loop":
                attn_out["pooled_p50_improvement_drain_over_loop"],
        }
    print(f"hybrid decode (loop): {ms['decode']}")
    print(f"hybrid state slots: {gauges} | pages: {page_gauges(eng)}")
    print(f"hybrid steady-state recompiles across churn: {loop_recompiles}")
    assert loop_recompiles == 0, "hybrid churn must not recompile"
    assert gauges["state_slots_in_use"] == 0, "state slots must drain"
    assert gauges["state_slots_peak"] >= 2, "churn must overlap streams"
    write_serving_section("hybrid", out, out_path)
    return out


def run_all(out_path: str = None, smoke: bool = False):
    global DECODE_STEPS, HORIZON, POOLED_RPS
    if smoke:
        DECODE_STEPS, HORIZON, POOLED_RPS = 16, 0.6, 30.0
    srv, cfg, loop = build()
    eng = srv.decode_engine("fm0")
    fm = srv.fms["fm0"]
    max_wall = 60.0 if smoke else 300.0

    loop.warmup(pooled_task="pooled", gen_task="gen0", pooled_n=8)
    compiles = eng.compile_count() + fm.compile_count()

    pooled = pooled_trace(cfg, HORIZON, POOLED_RPS)
    gen = gen_trace(cfg, HORIZON, DECODE_STEPS)

    def fresh_sched():
        # comparable virtual-tag state per mode: scheduler state from one
        # mode's (token-heavy) run must not leak into the next mode's tags
        srv.deploy_fm("fm0", profile=srv.profiles["fm0"], scheduler="bfq")

    fresh_sched()
    solo = run_loop(loop, pooled, max_wall)
    solo_stats = latency_stats([r for r in solo if r.max_new_tokens <= 0])

    fresh_sched()
    loop.ticks.clear()         # report the MIXED run's interleaving only
    loop.page_samples.clear()  # occupancy of the measured run only
    loop.shared_samples.clear()
    mixed = run_loop(loop, pooled + gen, max_wall)
    ms = mixed_stats(mixed, page_samples=loop.page_samples,
                     shared_samples=loop.shared_samples)
    loop_pooled, loop_decode = ms["pooled"], ms["decode"]
    loop_kv_pages = ms.get("kv_pages", {})
    loop_kv_sharing = ms.get("kv_sharing", {})
    loop_gen_lat = latency_stats([r for r in mixed if r.max_new_tokens > 0])
    loop_recompiles = eng.compile_count() + fm.compile_count() - compiles

    fresh_sched()
    drained = run_drain(srv, pooled + gen, max_wall)
    drain_pooled = latency_stats([r for r in drained
                                  if r.max_new_tokens <= 0])
    drain_decode = decode_stats([r for r in drained if r.max_new_tokens > 0])
    drain_gen_lat = latency_stats([r for r in drained
                                   if r.max_new_tokens > 0])

    improvement = drain_pooled.get("p50_ms", float("nan")) / \
        max(loop_pooled.get("p50_ms", float("nan")), 1e-9)
    out = {
        "config": cfg.name,
        "prompt_len": PROMPT_LEN,
        "decode_steps": DECODE_STEPS,
        "pooled_rps": POOLED_RPS,
        "gen_tasks": N_GEN_TASKS,
        "horizon_s": HORIZON,
        "pooled_solo": solo_stats,
        "mixed_loop": {"pooled": loop_pooled, "decode": loop_decode,
                       "decode_latency": loop_gen_lat,
                       "kv_pages": loop_kv_pages,
                       "kv_sharing": loop_kv_sharing,
                       "ticks": dict(loop.ticks)},
        "engine_pages": page_gauges(eng),
        "mixed_drain": {"pooled": drain_pooled, "decode": drain_decode,
                        "decode_latency": drain_gen_lat},
        "pooled_p50_improvement_drain_over_loop": round(improvement, 2),
        "loop_beats_drain_pooled_p50": bool(improvement > 1.0),
        "steady_state_recompiles_mixed_churn": loop_recompiles,
        "prompt_buckets": list(eng.prompt_buckets),
    }
    print(f"pooled p50: solo={solo_stats.get('p50_ms', float('nan')):.1f}ms "
          f"loop={loop_pooled.get('p50_ms', float('nan')):.1f}ms "
          f"drain={drain_pooled.get('p50_ms', float('nan')):.1f}ms "
          f"(drain/loop x{improvement:.2f})")
    print(f"decode (loop): {loop_decode}")
    print(f"kv pages (loop): {loop_kv_pages} sharing={loop_kv_sharing} "
          f"| {page_gauges(eng)}")
    print(f"steady-state recompiles across mixed churn: {loop_recompiles}")
    assert loop_recompiles == 0, "mixed churn must not recompile"
    write_serving_section("mixed", out, out_path)
    # the hybrid leg rides the same invocation: one engine per FM, reported
    # side by side — the attention FM's numbers above are already written
    # and unchanged by it
    run_hybrid(out_path=out_path, smoke=smoke, attn_out=out)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: short horizon, 16-step decodes")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    run_all(out_path=args.out, smoke=args.smoke)
