"""Paged vs dense int8 KV pool benchmark (the PR's acceptance numbers).

Two claims, measured on the same reduced decoder backbone:

  * **capacity** — at FIXED KV memory (equal token capacity), the paged pool
    sustains >= 2x more concurrent streams than the dense pool on a
    mixed-length workload (log-uniform decode budgets: most streams short,
    a heavy tail long). The dense pool reserves ``s_max`` tokens per slot,
    so its concurrency is its slot count regardless of what streams actually
    use; the paged pool hands out pages on demand and recycles them at
    retire, so short streams stop paying for the tail's worst case.
  * **step-time parity** — at EQUAL occupancy (same number of live streams,
    same slot bucket), chunked decode through the paged arena stays within
    ~10% of the dense int8 path: the page gather rides the same
    online-softmax stream (index-map gather on TPU, jnp gather on the CPU
    oracle), so paging buys memory without a hot-path regression.

Plus the steady-state invariant: churn with page allocation/recycling and
deferred admissions adds ZERO jitted executables.

And the **page-size sweep** (8/16/32/64 at the same fixed token budget):
small pages cut last-page fragmentation (waste ~ page_size/2 per stream) but
widen the page table and shrink per-DMA transfers; the sweep records peak
concurrency, measured fragmentation (held-page slack over held capacity) and
decode step time per size, so the fragmentation-vs-table-width knee is a
number, not folklore. CPU-measured; re-run on TPU before trusting the knee
there (the DMA economics differ — see ROADMAP).

Results land under the "paged" section of ``BENCH_serving.json`` with the
same warmup / median-of-repeats / backend + jax-version stamping as the
other serving sections.
"""
from __future__ import annotations

import argparse
import statistics
import time

import jax
import numpy as np

from common import write_serving_section
from repro.configs import get_config, reduced
from repro.core.decode_engine import DecodeEngine
from repro.core.physical import PhysicalFM

PROMPT_LEN = 16
MAX_NEW = 128                 # the dense pool reserves for this worst case
PAGE_SIZE = 16
DENSE_SLOTS = 4               # fixes the KV memory budget
PAGED_SLOTS = 32
N_STREAMS = 32
PARITY_SLOTS = 8
PARITY_STEPS = 64
CHUNK = 8
WARMUP = 1
REPEATS = 5
PAGE_SIZE_SWEEP = (8, 16, 32, 64)


def _fm(cfg, num_adapters: int = 4) -> PhysicalFM:
    fm = PhysicalFM(cfg, seed=0, input_len=PROMPT_LEN, lora_rank=8,
                    lora_impl="segmented", seg_block_t=16)
    for i in range(num_adapters):
        tree = fm.adapters._mod.init_single_adapter(
            jax.random.PRNGKey(i), fm.cfg, fm.adapters.rank)
        leaves, tdef = jax.tree.flatten(tree)
        ks = jax.random.split(jax.random.PRNGKey(1000 + i), len(leaves))
        fm.adapters.add(f"lora{i}", jax.tree.unflatten(tdef, [
            jax.random.normal(k, l.shape, l.dtype) * 0.05
            for k, l in zip(ks, leaves)]))
    return fm


def mixed_length_workload(cfg, n: int, max_new: int, seed: int = 0):
    """(prompt, budget) pairs with log-uniform budgets in [8, max_new] and
    ragged prompts — the trace shape that makes dense reservation wasteful."""
    rng = np.random.RandomState(seed)
    out = []
    for i in range(n):
        plen = int(rng.randint(max(1, PROMPT_LEN // 4), PROMPT_LEN + 1))
        new = int(round(np.exp(rng.uniform(np.log(8), np.log(max_new + 1)))))
        out.append((rng.randint(0, cfg.vocab_size, plen).astype(np.int32),
                    max(8, min(new, max_new))))
    return out


def drive_capacity(eng: DecodeEngine, work, names) -> dict:
    """Burst-admit the whole workload, then drain; the engine's admission
    policy (dense: slot-gated; paged: page-gated with deferral) decides how
    many streams actually run concurrently."""
    t0 = time.perf_counter()
    done = []
    peak_frag = 0.0
    for i, (prompt, new) in enumerate(work):
        if not eng.paged:
            while not eng.free_slots():
                done += eng.step_chunk()
        eng.join(f"t{i}", prompt, adapter_id=names[i % len(names)],
                 max_new_tokens=new, rid=i)
    peak = eng.active_count()
    peak_pages = eng.used_page_count()
    while eng.active_count() or eng.pending_count():
        done += eng.step_chunk()
        peak = max(peak, eng.active_count())
        peak_pages = max(peak_pages, eng.used_page_count())
        if eng.paged:
            held = int(eng._held.sum())
            if held:
                # last-page slack: tokens of held capacity not backing a
                # real token — THE fragmentation cost of a page size
                frag = 1.0 - float(eng._lens.sum()) / (held * eng.page_size)
                peak_frag = max(peak_frag, frag)
    wall = time.perf_counter() - t0
    toks = sum(len(d.tokens) for d in done)
    assert len(done) == len(work), (len(done), len(work))
    return {"streams_served": len(done), "peak_concurrent_streams": peak,
            "peak_used_pages": peak_pages,
            "peak_fragmentation": round(peak_frag, 4),
            "tokens_out": toks,
            "tokens_per_s": round(toks / wall, 1),
            "wall_s": round(wall, 3)}


def parity_step_time(fm, cfg, *, paged: bool, steps: int, repeats: int,
                     seed: int = 7, page_size: int = None) -> list[float]:
    """Median-of-chunks decode ms/step at FULL occupancy (all slots live)."""
    kw = dict(num_slots=PARITY_SLOTS, prompt_len=PROMPT_LEN, max_new=steps,
              chunk=CHUNK)
    if paged:                                        # dense-equivalent pages
        kw.update(paged=True, page_size=page_size or PAGE_SIZE)
    eng = DecodeEngine(fm, **kw)
    rng = np.random.RandomState(seed)
    prompts = rng.randint(0, cfg.vocab_size,
                          (PARITY_SLOTS, PROMPT_LEN)).astype(np.int32)
    names = [f"lora{i % 4}" for i in range(PARITY_SLOTS)]
    per_rep = []
    for rep in range(WARMUP + repeats):
        for i in range(PARITY_SLOTS):
            eng.join(f"t{i}", prompts[i], adapter_id=names[i],
                     max_new_tokens=steps, rid=i)
        jax.block_until_ready(eng.pool)
        chunk_s = []
        while eng.active_count():
            t0 = time.perf_counter()
            eng.step_chunk()
            chunk_s.append(time.perf_counter() - t0)
        if rep >= WARMUP:
            # drop the retire chunk (host bookkeeping, not steady decode)
            steady = chunk_s[:-1] if len(chunk_s) > 1 else chunk_s
            per_rep.append(1e3 * statistics.median(steady) / CHUNK)
    return per_rep


def page_size_sweep(fm, cfg, names, sizes, *, repeats: int) -> dict:
    """Same fixed KV token budget, page size swept over ``sizes``: capacity
    on the mixed-length workload (with the measured peak last-page
    fragmentation) plus steady decode ms/step at fixed occupancy — the two
    sides of the page-size trade (waste vs table width / transfer size)."""
    budget_tokens = DENSE_SLOTS * (PROMPT_LEN + MAX_NEW + 1)
    work = mixed_length_workload(cfg, N_STREAMS, MAX_NEW)
    out = {}
    for ps in sizes:
        eng = DecodeEngine(fm, num_slots=PAGED_SLOTS, prompt_len=PROMPT_LEN,
                           max_new=MAX_NEW, chunk=CHUNK, paged=True,
                           page_size=ps,
                           total_pages=1 + budget_tokens // ps)
        cap = drive_capacity(eng, work, names)
        ms = statistics.median(parity_step_time(
            fm, cfg, paged=True, steps=PARITY_STEPS, repeats=repeats,
            page_size=ps))
        out[str(ps)] = {
            "total_pages": 1 + budget_tokens // ps,
            "table_width": eng.pages_per_slot,
            "peak_concurrent_streams": cap["peak_concurrent_streams"],
            "peak_fragmentation": cap["peak_fragmentation"],
            "tokens_per_s": cap["tokens_per_s"],
            "decode_ms_per_step": round(ms, 3),
        }
        print(f"page_size={ps}: peak {cap['peak_concurrent_streams']} "
              f"streams, frag {cap['peak_fragmentation']:.3f}, "
              f"table width {eng.pages_per_slot}, {ms:.2f}ms/step")
    return out


def run_all(out_path: str = None, smoke: bool = False):
    global MAX_NEW, N_STREAMS, PARITY_STEPS, REPEATS, PAGE_SIZE_SWEEP
    if smoke:
        MAX_NEW, N_STREAMS, PARITY_STEPS, REPEATS = 32, 12, 16, 1
        PAGE_SIZE_SWEEP = (8, 32)
    cfg = reduced(get_config("stablelm-1.6b"))
    fm = _fm(cfg)
    names = [f"lora{i}" for i in range(4)]

    # ---- capacity at fixed KV memory ----
    s_max = PROMPT_LEN + MAX_NEW + 1
    budget_tokens = DENSE_SLOTS * s_max              # the dense reservation
    total_pages = 1 + budget_tokens // PAGE_SIZE     # +1: reserved trash page
    work = mixed_length_workload(cfg, N_STREAMS, MAX_NEW)
    dense = DecodeEngine(fm, num_slots=DENSE_SLOTS, prompt_len=PROMPT_LEN,
                         max_new=MAX_NEW, chunk=CHUNK)
    cap_dense = drive_capacity(dense, work, names)
    paged = DecodeEngine(fm, num_slots=PAGED_SLOTS, prompt_len=PROMPT_LEN,
                         max_new=MAX_NEW, chunk=CHUNK, paged=True,
                         page_size=PAGE_SIZE, total_pages=total_pages)
    cap_paged = drive_capacity(paged, work, names)
    ratio = cap_paged["peak_concurrent_streams"] / \
        max(cap_dense["peak_concurrent_streams"], 1)
    print(f"capacity @ {budget_tokens} KV tokens: dense peak "
          f"{cap_dense['peak_concurrent_streams']} streams, paged peak "
          f"{cap_paged['peak_concurrent_streams']} streams (x{ratio:.1f}), "
          f"paged deferrals={paged.deferrals} preemptions={paged.preemptions}")

    # ---- decode step time at equal occupancy ----
    d_ms = parity_step_time(fm, cfg, paged=False, steps=PARITY_STEPS,
                            repeats=REPEATS)
    p_ms = parity_step_time(fm, cfg, paged=True, steps=PARITY_STEPS,
                            repeats=REPEATS)
    dense_ms = statistics.median(d_ms)
    paged_ms = statistics.median(p_ms)
    overhead = paged_ms / max(dense_ms, 1e-9)
    print(f"decode @ occupancy {PARITY_SLOTS}: dense {dense_ms:.2f}ms/step, "
          f"paged {paged_ms:.2f}ms/step (x{overhead:.2f})")

    # ---- steady state: churn + page alloc must not recompile ----
    eng = DecodeEngine(fm, num_slots=4, prompt_len=PROMPT_LEN, max_new=16,
                       chunk=4, paged=True, page_size=PAGE_SIZE)
    rng = np.random.RandomState(0)
    prompts = rng.randint(0, cfg.vocab_size, (8, PROMPT_LEN)).astype(np.int32)
    for i in range(4):
        eng.join(f"t{i}", prompts[i][:4 + 3 * i], adapter_id=names[i % 2],
                 max_new_tokens=6 + i, rid=i)
    eng.drain()                                     # warm all executables
    compiles_before = eng.compile_count()
    for i in range(4, 8):                           # churn: new compositions
        eng.join(f"t{i}", prompts[i][:3 + 3 * (i % 4)],
                 adapter_id=names[(i + 1) % 2], max_new_tokens=5 + i % 3,
                 rid=i)
    eng.drain()
    steady = {
        "recompiles_after_churn": eng.compile_count() - compiles_before,
        "free_pages_after_drain": eng.free_page_count(),
        "total_usable_pages": eng.total_pages - 1,
    }
    print("steady state:", steady)
    assert steady["recompiles_after_churn"] == 0, steady
    assert steady["free_pages_after_drain"] == steady["total_usable_pages"]

    # ---- page-size sweep: fragmentation vs table width ----
    sweep = page_size_sweep(fm, cfg, names, PAGE_SIZE_SWEEP,
                            repeats=max(1, REPEATS // 2))

    out = {
        "config": cfg.name,
        "prompt_len": PROMPT_LEN,
        "max_new": MAX_NEW,
        "page_size": PAGE_SIZE,
        "chunk": CHUNK,
        "warmup": WARMUP,
        "repeats": REPEATS,
        "stat": "median",
        "capacity": {
            "kv_budget_tokens": budget_tokens,
            "total_pages": total_pages,
            "workload_streams": N_STREAMS,
            "dense": cap_dense,
            "paged": dict(cap_paged, deferrals=paged.deferrals,
                          preemptions=paged.preemptions),
            "concurrency_ratio": round(ratio, 2),
        },
        "step_parity": {
            "occupancy": PARITY_SLOTS,
            "decode_steps": PARITY_STEPS,
            "dense_ms_per_step": round(dense_ms, 3),
            "paged_ms_per_step": round(paged_ms, 3),
            "paged_over_dense": round(overhead, 3),
        },
        "steady_state": steady,
        "page_size_sweep": sweep,
        "paged_2x_streams_at_fixed_memory": bool(ratio >= 2.0),
        "paged_step_within_10pct": bool(overhead <= 1.10),
    }
    write_serving_section("paged", out, out_path)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: small workload, 1 repeat")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    run_all(out_path=args.out, smoke=args.smoke)
