"""Copy-on-write prefix sharing benchmark (the PR's acceptance numbers).

Three claims, measured on the same reduced decoder backbone against the
SAME paged engine with sharing disabled (so the only variable is COW):

  * **capacity** — at FIXED KV memory (same ``total_pages``), an
    80%-shared-prefix workload (the multi-task system-prompt shape: most
    requests repeat one of a few long few-shot prefixes, each with a short
    unique user suffix) sustains >= 3x more peak concurrent streams with
    prefix sharing than without: sharers MAP the registered prefix pages
    (refcounted) and only allocate their private tails, so the arena stops
    storing the same prompt once per stream.
  * **exact token parity** — every stream's tokens match the unshared
    engine's token for token. Admission quantizes per (page, kv-head) — a
    page's scale is a pure function of the tokens it covers — so a shared
    page is bit-identical to what the sharer's own prefill would have
    written, and sharing is a memory dedup, not a numeric change.
  * **zero steady-state recompiles** — sharer join/leave/preemption churn
    reuses the warmed executables: page ids (shared positions pointed at
    the trash page), tables and lengths are all traced operands.
  * **TTFT (chunked shared-prefix prefill)** — a prefix-hit join prefills
    only its private tail (attending the mapped pages' float sidecars), so
    its admission latency drops >= 2x against the full-prefill path on the
    identical trace, at bit-identical first tokens and zero recompiles
    after ``warm_chunked``. Lands under ``prefix.ttft``.

Results land under the "prefix" section of ``BENCH_serving.json`` with the
same backend/jax-version stamping as the other serving sections.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from common import write_serving_section
from repro.configs import get_config, reduced
from repro.core.decode_engine import DecodeEngine
from repro.core.physical import PhysicalFM

PAGE_SIZE = 16
PREFIX_LEN = 480              # 30 pages of shared few-shot/system prompt
SUFFIX_MAX = 16               # unique user tail
PROMPT_LEN = PREFIX_LEN + SUFFIX_MAX
MAX_NEW = 8
CHUNK = 4
N_STREAMS = 32
N_PREFIXES = 1
SHARED_FRAC = 0.8
NUM_SLOTS = 32
TOTAL_PAGES = 1 + 256         # fixed KV memory: 256 usable pages = 4096
                              # tokens — 8 full unshared streams


def _fm(cfg, num_adapters: int = 2) -> PhysicalFM:
    fm = PhysicalFM(cfg, seed=0, input_len=PROMPT_LEN, lora_rank=8,
                    lora_impl="segmented", seg_block_t=16)
    for i in range(num_adapters):
        tree = fm.adapters._mod.init_single_adapter(
            jax.random.PRNGKey(i), fm.cfg, fm.adapters.rank)
        leaves, tdef = jax.tree.flatten(tree)
        ks = jax.random.split(jax.random.PRNGKey(1000 + i), len(leaves))
        fm.adapters.add(f"lora{i}", jax.tree.unflatten(tdef, [
            jax.random.normal(k, l.shape, l.dtype) * 0.05
            for k, l in zip(ks, leaves)]))
    return fm


def shared_prefix_workload(cfg, n: int, seed: int = 0):
    """(prompt, budget) pairs: ``SHARED_FRAC`` of the streams carry one of
    ``N_PREFIXES`` fixed page-aligned prefixes + a unique suffix, the rest
    are fully random — the 80%-shared trace of the acceptance criterion."""
    rng = np.random.RandomState(seed)
    prefixes = [rng.randint(0, cfg.vocab_size, PREFIX_LEN).astype(np.int32)
                for _ in range(N_PREFIXES)]
    out = []
    for i in range(n):
        new = int(rng.randint(2, MAX_NEW + 1))
        if rng.rand() < SHARED_FRAC:
            sfx = rng.randint(0, cfg.vocab_size, int(
                rng.randint(1, SUFFIX_MAX + 1))).astype(np.int32)
            prompt = np.concatenate([prefixes[rng.randint(N_PREFIXES)], sfx])
        else:
            prompt = rng.randint(0, cfg.vocab_size, int(
                rng.randint(PREFIX_LEN // 2, PROMPT_LEN + 1))).astype(
                np.int32)
        out.append((prompt, new))
    return prefixes, out


def make_engine(fm, *, sharing: bool, chunked: bool = True) -> DecodeEngine:
    # the deep pending-queue lookahead lets the drain admit every stream
    # the pages can serve during the burst (a CI-sized fairness cap would
    # throttle the measurement, not the memory)
    return DecodeEngine(fm, num_slots=NUM_SLOTS, prompt_len=PROMPT_LEN,
                        max_new=MAX_NEW, chunk=CHUNK, paged=True,
                        page_size=PAGE_SIZE, total_pages=TOTAL_PAGES,
                        prefix_sharing=sharing, chunked_prefill=chunked,
                        prompt_buckets=(PROMPT_LEN,),
                        pending_lookahead=2 * N_STREAMS,
                        hol_skip_cap=2 * N_STREAMS)


def warm(eng, cfg, seed: int = 123):
    """Compile every executable a run can touch (prefill per bucket, the
    chunked tail planes per tail bucket, pool write, decode chunk) with a
    throwaway stream."""
    rng = np.random.RandomState(seed)
    for plen in eng.prompt_buckets:
        eng.join("warm", rng.randint(0, cfg.vocab_size, plen),
                 adapter_id="lora0", max_new_tokens=2, rid=-1)
        eng.drain()
    eng.warm_chunked()                  # no-op unless chunked_prefill


def drive(eng: DecodeEngine, work) -> dict:
    """Burst-admit the whole workload, then drain; the engine's memory gate
    (with the sharing discount when enabled) decides the real concurrency."""
    t0 = time.perf_counter()
    done = {}
    for i, (prompt, new) in enumerate(work):
        eng.join(f"t{i}", prompt, adapter_id="lora0", max_new_tokens=new,
                 rid=i)
    peak = eng.active_count()
    peak_pages = eng.used_page_count()
    peak_saved = eng.dedup_saved_pages()
    while eng.active_count() or eng.pending_count():
        for d in eng.step_chunk():
            done[d.rid] = d.tokens
        peak = max(peak, eng.active_count())
        peak_pages = max(peak_pages, eng.used_page_count())
        peak_saved = max(peak_saved, eng.dedup_saved_pages())
    wall = time.perf_counter() - t0
    assert len(done) == len(work), (len(done), len(work))
    toks = sum(len(t) for t in done.values())
    return {"streams_served": len(done),
            "peak_concurrent_streams": peak,
            "peak_used_pages": peak_pages,
            "peak_dedup_saved_pages": peak_saved,
            "prefix_hits": eng.prefix_hits,
            "deferrals": eng.deferrals,
            "preemptions": eng.preemptions,
            "tokens_out": toks,
            "tokens_per_s": round(toks / wall, 1),
            "wall_s": round(wall, 3),
            "tokens": done}


def bench_ttft(fm, cfg, prefixes, work) -> dict:
    """Admission TTFT (join wall time: prefill + sample + page scatter) for
    every stream of the trace, measured one join at a time against a LIVE
    holder per prefix — on the chunked engine and on an engine identical
    except ``chunked_prefill=False``. Prefix-hit joins on the chunked
    engine prefill only their private tail; the full engine recomputes the
    whole prompt (while still mapping the shared pages — the COW dedup is
    held constant, so the delta is purely the skipped prefill compute)."""
    is_hit = [len(p) > PREFIX_LEN
              and any((p[:PREFIX_LEN] == pre).all() for pre in prefixes)
              for p, _ in work]
    stats, firsts = {}, {}
    for name, chunked in (("chunked", True), ("full", False)):
        eng = make_engine(fm, sharing=True, chunked=chunked)
        warm(eng, cfg)
        before = eng.compile_count()
        hrng = np.random.RandomState(9)
        for j, pre in enumerate(prefixes):   # keep the prefix registered
            eng.join(f"hold{j}", np.concatenate(
                [pre, hrng.randint(0, cfg.vocab_size, 1).astype(np.int32)]),
                adapter_id="lora0", max_new_tokens=MAX_NEW, rid=-10 - j)
        dts, first = [], []
        for i, (p, new) in enumerate(work):
            t0 = time.perf_counter()
            slot = eng.join(f"m{i}", p, adapter_id="lora0",
                            max_new_tokens=new, rid=10_000 + i)
            dts.append(time.perf_counter() - t0)
            first.append(int(eng.slots[slot].tokens[0]))
            eng.leave(slot)                  # join/leave churn by design
        assert eng.compile_count() == before, "TTFT churn recompiled"
        eng.drain()
        assert eng.free_page_count() == eng.total_pages - 1
        stats[name] = dts
        firsts[name] = first
    hit_ms = {n: 1e3 * float(np.median(
        [d for d, h in zip(dts, is_hit) if h]))
        for n, dts in stats.items()}
    miss = [d for d, h in zip(stats["chunked"], is_hit) if not h]
    miss_full = [d for d, h in zip(stats["full"], is_hit) if not h]
    reduction = hit_ms["full"] / max(hit_ms["chunked"], 1e-9)
    return {
        "prefix_hit_joins": int(sum(is_hit)),
        "prefix_miss_joins": int(len(work) - sum(is_hit)),
        "chunked_hit_ttft_ms_p50": round(hit_ms["chunked"], 3),
        "full_hit_ttft_ms_p50": round(hit_ms["full"], 3),
        "chunked_miss_ttft_ms_p50": round(
            1e3 * float(np.median(miss)), 3) if miss else None,
        "full_miss_ttft_ms_p50": round(
            1e3 * float(np.median(miss_full)), 3) if miss_full else None,
        "hit_ttft_reduction": round(reduction, 2),
        "first_token_parity": firsts["chunked"] == firsts["full"],
        "ttft_2x_reduction": bool(reduction >= 2.0),
    }


def run_all(out_path: str = None, smoke: bool = False):
    global N_STREAMS
    if smoke:
        N_STREAMS = 12
    cfg = reduced(get_config("stablelm-1.6b"))
    fm = _fm(cfg)
    prefixes, work = shared_prefix_workload(cfg, N_STREAMS)

    results = {}
    compiles = {}
    for name, sharing in (("shared", True), ("unshared", False)):
        eng = make_engine(fm, sharing=sharing)
        warm(eng, cfg)
        before = eng.compile_count()
        results[name] = drive(eng, work)
        compiles[name] = eng.compile_count() - before
        assert eng.free_page_count() == eng.total_pages - 1

    ratio = results["shared"]["peak_concurrent_streams"] / \
        max(results["unshared"]["peak_concurrent_streams"], 1)
    # the shared engine runs CHUNKED admissions (the default): stream-level
    # parity against the unshared full-prefill engine is ALSO the chunked
    # vs full exactness check, over the whole trace's churn
    parity = results["shared"].pop("tokens") == \
        results["unshared"].pop("tokens")
    print(f"capacity @ {(TOTAL_PAGES - 1) * PAGE_SIZE} KV tokens: unshared "
          f"peak {results['unshared']['peak_concurrent_streams']} streams, "
          f"shared peak {results['shared']['peak_concurrent_streams']} "
          f"streams (x{ratio:.1f}), dedup peak "
          f"{results['shared']['peak_dedup_saved_pages']} pages, "
          f"token parity {parity}, recompiles {compiles}")
    assert parity, "prefix sharing changed a token stream"
    assert compiles == {"shared": 0, "unshared": 0}, compiles

    ttft = bench_ttft(fm, cfg, prefixes, work)
    print(f"ttft: prefix-hit joins p50 {ttft['chunked_hit_ttft_ms_p50']}ms "
          f"chunked vs {ttft['full_hit_ttft_ms_p50']}ms full "
          f"(x{ttft['hit_ttft_reduction']}), first-token parity "
          f"{ttft['first_token_parity']}")
    assert ttft["first_token_parity"], "chunked admission changed a token"
    assert ttft["hit_ttft_reduction"] > (1.0 if smoke else 2.0), ttft

    out = {
        "config": cfg.name,
        "page_size": PAGE_SIZE,
        "prefix_len": PREFIX_LEN,
        "suffix_max": SUFFIX_MAX,
        "max_new": MAX_NEW,
        "chunk": CHUNK,
        "shared_frac": SHARED_FRAC,
        "n_prefixes": N_PREFIXES,
        "workload_streams": N_STREAMS,
        "kv_budget_tokens": (TOTAL_PAGES - 1) * PAGE_SIZE,
        "total_pages": TOTAL_PAGES,
        "unshared": results["unshared"],
        "shared": results["shared"],
        "concurrency_ratio": round(ratio, 2),
        "token_parity": bool(parity),
        "recompiles_after_warm": compiles,
        "prefix_3x_streams_at_fixed_memory": bool(ratio >= 3.0),
        "ttft": ttft,
    }
    write_serving_section("prefix", out, out_path)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: small workload")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    run_all(out_path=args.out, smoke=args.smoke)
