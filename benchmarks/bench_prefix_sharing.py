"""Copy-on-write prefix sharing benchmark (the PR's acceptance numbers).

Three claims, measured on the same reduced decoder backbone against the
SAME paged engine with sharing disabled (so the only variable is COW):

  * **capacity** — at FIXED KV memory (same ``total_pages``), an
    80%-shared-prefix workload (the multi-task system-prompt shape: most
    requests repeat one of a few long few-shot prefixes, each with a short
    unique user suffix) sustains >= 3x more peak concurrent streams with
    prefix sharing than without: sharers MAP the registered prefix pages
    (refcounted) and only allocate their private tails, so the arena stops
    storing the same prompt once per stream.
  * **exact token parity** — every stream's tokens match the unshared
    engine's token for token. Admission quantizes per (page, kv-head) — a
    page's scale is a pure function of the tokens it covers — so a shared
    page is bit-identical to what the sharer's own prefill would have
    written, and sharing is a memory dedup, not a numeric change.
  * **zero steady-state recompiles** — sharer join/leave/preemption churn
    reuses the warmed executables: page ids (shared positions pointed at
    the trash page), tables and lengths are all traced operands.

Results land under the "prefix" section of ``BENCH_serving.json`` with the
same backend/jax-version stamping as the other serving sections.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from common import write_serving_section
from repro.configs import get_config, reduced
from repro.core.decode_engine import DecodeEngine
from repro.core.physical import PhysicalFM

PAGE_SIZE = 16
PREFIX_LEN = 96               # 6 pages of shared few-shot/system prompt
SUFFIX_MAX = 16               # unique user tail
PROMPT_LEN = PREFIX_LEN + SUFFIX_MAX
MAX_NEW = 8
CHUNK = 4
N_STREAMS = 32
N_PREFIXES = 1
SHARED_FRAC = 0.8
NUM_SLOTS = 32
TOTAL_PAGES = 1 + 56          # fixed KV memory: 56 usable pages = 896 tokens


def _fm(cfg, num_adapters: int = 2) -> PhysicalFM:
    fm = PhysicalFM(cfg, seed=0, input_len=PROMPT_LEN, lora_rank=8,
                    lora_impl="segmented", seg_block_t=16)
    for i in range(num_adapters):
        tree = fm.adapters._mod.init_single_adapter(
            jax.random.PRNGKey(i), fm.cfg, fm.adapters.rank)
        leaves, tdef = jax.tree.flatten(tree)
        ks = jax.random.split(jax.random.PRNGKey(1000 + i), len(leaves))
        fm.adapters.add(f"lora{i}", jax.tree.unflatten(tdef, [
            jax.random.normal(k, l.shape, l.dtype) * 0.05
            for k, l in zip(ks, leaves)]))
    return fm


def shared_prefix_workload(cfg, n: int, seed: int = 0):
    """(prompt, budget) pairs: ``SHARED_FRAC`` of the streams carry one of
    ``N_PREFIXES`` fixed page-aligned prefixes + a unique suffix, the rest
    are fully random — the 80%-shared trace of the acceptance criterion."""
    rng = np.random.RandomState(seed)
    prefixes = [rng.randint(0, cfg.vocab_size, PREFIX_LEN).astype(np.int32)
                for _ in range(N_PREFIXES)]
    out = []
    for i in range(n):
        new = int(rng.randint(2, MAX_NEW + 1))
        if rng.rand() < SHARED_FRAC:
            sfx = rng.randint(0, cfg.vocab_size, int(
                rng.randint(1, SUFFIX_MAX + 1))).astype(np.int32)
            prompt = np.concatenate([prefixes[rng.randint(N_PREFIXES)], sfx])
        else:
            prompt = rng.randint(0, cfg.vocab_size, int(
                rng.randint(PREFIX_LEN // 2, PROMPT_LEN + 1))).astype(
                np.int32)
        out.append((prompt, new))
    return out


def make_engine(fm, *, sharing: bool) -> DecodeEngine:
    # the deep pending-queue lookahead lets the drain admit every stream
    # the pages can serve during the burst (a CI-sized fairness cap would
    # throttle the measurement, not the memory)
    return DecodeEngine(fm, num_slots=NUM_SLOTS, prompt_len=PROMPT_LEN,
                        max_new=MAX_NEW, chunk=CHUNK, paged=True,
                        page_size=PAGE_SIZE, total_pages=TOTAL_PAGES,
                        prefix_sharing=sharing,
                        prompt_buckets=(PROMPT_LEN,),
                        pending_lookahead=2 * N_STREAMS,
                        hol_skip_cap=2 * N_STREAMS)


def warm(eng, cfg, seed: int = 123):
    """Compile every executable a run can touch (prefill per bucket, pool
    write, decode chunk) with a throwaway stream."""
    rng = np.random.RandomState(seed)
    for plen in eng.prompt_buckets:
        eng.join("warm", rng.randint(0, cfg.vocab_size, plen),
                 adapter_id="lora0", max_new_tokens=2, rid=-1)
        eng.drain()


def drive(eng: DecodeEngine, work) -> dict:
    """Burst-admit the whole workload, then drain; the engine's memory gate
    (with the sharing discount when enabled) decides the real concurrency."""
    t0 = time.perf_counter()
    done = {}
    for i, (prompt, new) in enumerate(work):
        eng.join(f"t{i}", prompt, adapter_id="lora0", max_new_tokens=new,
                 rid=i)
    peak = eng.active_count()
    peak_pages = eng.used_page_count()
    peak_saved = eng.dedup_saved_pages()
    while eng.active_count() or eng.pending_count():
        for d in eng.step_chunk():
            done[d.rid] = d.tokens
        peak = max(peak, eng.active_count())
        peak_pages = max(peak_pages, eng.used_page_count())
        peak_saved = max(peak_saved, eng.dedup_saved_pages())
    wall = time.perf_counter() - t0
    assert len(done) == len(work), (len(done), len(work))
    toks = sum(len(t) for t in done.values())
    return {"streams_served": len(done),
            "peak_concurrent_streams": peak,
            "peak_used_pages": peak_pages,
            "peak_dedup_saved_pages": peak_saved,
            "prefix_hits": eng.prefix_hits,
            "deferrals": eng.deferrals,
            "preemptions": eng.preemptions,
            "tokens_out": toks,
            "tokens_per_s": round(toks / wall, 1),
            "wall_s": round(wall, 3),
            "tokens": done}


def run_all(out_path: str = None, smoke: bool = False):
    global N_STREAMS
    if smoke:
        N_STREAMS = 12
    cfg = reduced(get_config("stablelm-1.6b"))
    fm = _fm(cfg)
    work = shared_prefix_workload(cfg, N_STREAMS)

    results = {}
    compiles = {}
    for name, sharing in (("shared", True), ("unshared", False)):
        eng = make_engine(fm, sharing=sharing)
        warm(eng, cfg)
        before = eng.compile_count()
        results[name] = drive(eng, work)
        compiles[name] = eng.compile_count() - before
        assert eng.free_page_count() == eng.total_pages - 1

    ratio = results["shared"]["peak_concurrent_streams"] / \
        max(results["unshared"]["peak_concurrent_streams"], 1)
    parity = results["shared"].pop("tokens") == \
        results["unshared"].pop("tokens")
    print(f"capacity @ {(TOTAL_PAGES - 1) * PAGE_SIZE} KV tokens: unshared "
          f"peak {results['unshared']['peak_concurrent_streams']} streams, "
          f"shared peak {results['shared']['peak_concurrent_streams']} "
          f"streams (x{ratio:.1f}), dedup peak "
          f"{results['shared']['peak_dedup_saved_pages']} pages, "
          f"token parity {parity}, recompiles {compiles}")
    assert parity, "prefix sharing changed a token stream"
    assert compiles == {"shared": 0, "unshared": 0}, compiles

    out = {
        "config": cfg.name,
        "page_size": PAGE_SIZE,
        "prefix_len": PREFIX_LEN,
        "suffix_max": SUFFIX_MAX,
        "max_new": MAX_NEW,
        "chunk": CHUNK,
        "shared_frac": SHARED_FRAC,
        "n_prefixes": N_PREFIXES,
        "workload_streams": N_STREAMS,
        "kv_budget_tokens": (TOTAL_PAGES - 1) * PAGE_SIZE,
        "total_pages": TOTAL_PAGES,
        "unshared": results["unshared"],
        "shared": results["shared"],
        "concurrency_ratio": round(ratio, 2),
        "token_parity": bool(parity),
        "recompiles_after_warm": compiles,
        "prefix_3x_streams_at_fixed_memory": bool(ratio >= 3.0),
    }
    write_serving_section("prefix", out, out_path)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: small workload")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    run_all(out_path=args.out, smoke=args.smoke)
