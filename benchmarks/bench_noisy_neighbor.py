"""Paper Fig. 13: noisy-neighbor burst with 3:1 weights. Client A spikes
5 -> 500 -> 5 RPS while client B holds 60 RPS; B's share must be protected."""
from benchmarks.common import emit
from repro.controller.profiles import get_profile
from repro.serving.loadgen import burst_trace, merge, poisson_trace
from repro.serving.metrics import fairness_timeline, jain_fairness
from repro.serving.simulator import build_single_gpu

MODES = ("fmplex", "s-stfq", "s-be", "be", "sp")


def run_all():
    rows = []
    prof = get_profile("moment-large")
    horizon = 45.0
    for mode in MODES:
        tasks = [{"task_id": "A", "weight": 3.0}, {"task_id": "B", "weight": 1.0}]
        sim, ok = build_single_gpu(mode, tasks, prof)
        if not ok:
            continue
        arr = merge([burst_trace("A", 5, 500, burst_start=15, burst_len=10,
                                 horizon=horizon, seed=1),
                     poisson_trace("B", 60, horizon, seed=2)])
        fin = sim.run(arr, horizon + 30)
        b_burst = sum(1 for r in fin if r.task_id == "B" and r.finish_time
                      and 15 <= r.finish_time < 25) / 10.0
        b_steady = sum(1 for r in fin if r.task_id == "B" and r.finish_time
                       and 5 <= r.finish_time < 15) / 10.0
        shares = {t: sum(1 for r in fin if r.task_id == t and r.finish_time
                         and 15 <= r.finish_time < 25) for t in ("A", "B")}
        f = jain_fairness(shares, {"A": 3.0, "B": 1.0})
        rows.append((f"fig13.{mode}.B_thr_during_burst_rps",
                     round(b_burst * 1e3), round(b_burst, 1)))
        rows.append((f"fig13.{mode}.B_retention_pct",
                     round(1e4 * b_burst / max(b_steady, 1e-9)),
                     round(100 * b_burst / max(b_steady, 1e-9), 1)))
        rows.append((f"fig13.{mode}.burst_fairness",
                     round(f * 1e6), round(f, 3)))
    return emit(rows)


if __name__ == "__main__":
    run_all()
