"""Paper Fig. 16: adaptation latency after a workload surge.

FMplex rebinding attaches the task's decoder to a RESIDENT backbone on another
server (task-state timescale); BE must cold-start a new backbone replica
(backbone-load timescale) while the backlog inflates latency.
"""
from benchmarks.common import emit
from repro.controller import (ClusterState, ElasticAdapter, MaxShare, Server,
                              TaskSpec)
from repro.controller.profiles import get_profile
from repro.core.request import SLO
from repro.serving.loadgen import burst_trace, merge, poisson_trace
from repro.serving.metrics import latency_stats
from repro.serving.simulator import SimGPU, SimInstance, Simulator


def _scenario(mode: str):
    """Task 'hot' surges 3 -> 40 RPS at t=20. A second moment-large backbone is
    already resident on server s1 serving task 'other'."""
    prof = get_profile("moment-large")
    g0, g1 = SimGPU("s0"), SimGPU("s1")
    i0 = SimInstance("fm0", prof, scheduler="bfq")
    i1 = SimInstance("fm1", prof, scheduler="bfq")
    g0.instances.append(i0)
    g1.instances.append(i1)
    sim = Simulator([g0, g1])
    i0.bind("hot", slo=SLO(1.0))
    i1.bind("other", slo=SLO(1.0))
    sim.route("hot", g0, i0)
    sim.route("other", g1, i1)

    surge_t = 20.0
    if mode == "fmplex":
        # Controller rebind: replicate 'hot' onto the resident fm1 (moves only
        # task-local state; ready after task_load_s)
        def rebind(s):
            i1.bind("hot", slo=SLO(1.0))
            s.route("hot", g1, i1, frac=1.0)    # split 50/50 with fm0
        sim.add_hook(surge_t + prof.task_load_s, rebind)
        ready = prof.task_load_s
    else:
        # BE: provision a NEW backbone replica on s1 (cold load), then shift
        def provision(s):
            i2 = SimInstance("fm2", prof, scheduler="s-be")
            i2.loading_until = 0.0              # load completed by hook time
            g1.instances.append(i2)
            i2.bind("hot", slo=SLO(1.0))
            s.route("hot", g1, i2, frac=1.0)
        sim.add_hook(surge_t + prof.load_time_s + prof.task_load_s, provision)
        ready = prof.load_time_s + prof.task_load_s

    arr = merge([burst_trace("hot", 3, 40, burst_start=surge_t, burst_len=30,
                             horizon=60, seed=1),
                 poisson_trace("other", 10, 60, seed=2)])
    fin = sim.run(arr, 90.0)
    return fin, ready


def run_all():
    rows = []
    for mode in ("fmplex", "be"):
        fin, ready = _scenario(mode)
        hot = [r for r in fin if r.task_id == "hot" and r.finish_time]
        during = latency_stats([r for r in hot if 20 <= r.arrival < 35])
        after = latency_stats([r for r in hot if 40 <= r.arrival < 50])
        rows.append((f"fig16.{mode}.ready_ms", round(ready * 1e6),
                     round(ready * 1e3, 1)))
        rows.append((f"fig16.{mode}.surge_mean_ms",
                     round(during["mean_ms"] * 1e3), round(during["mean_ms"], 1)))
        rows.append((f"fig16.{mode}.post_mean_ms",
                     round(after["mean_ms"] * 1e3), round(after["mean_ms"], 1)))
    return emit(rows)


if __name__ == "__main__":
    run_all()
