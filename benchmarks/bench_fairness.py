"""Paper Fig. 12: task-level fairness + aggregate throughput under configured
service weights 1:1 / 2:1 / 3:1 at 60 RPS per client."""
from benchmarks.common import emit, run_mode
from repro.serving.metrics import jain_fairness

MODES = ("fmplex", "s-stfq", "s-be", "be", "sp")


def run_all():
    rows = []
    for wa, wb in ((1, 1), (2, 1), (3, 1)):
        for mode in MODES:
            fin, ok, _ = run_mode(mode, 2, rps_per_task=60, horizon=20.0,
                                  weights=[wa, wb], drain=60.0)
            if not ok:
                continue
            done = [r for r in fin if r.finish_time and r.finish_time <= 20]
            shares = {t: sum(1 for r in done if r.task_id == t)
                      for t in ("t0", "t1")}
            f = jain_fairness(shares, {"t0": wa, "t1": wb})
            thr = sum(shares.values()) / 20.0
            rows.append((f"fig12.{mode}.w{wa}:{wb}.fairness",
                         round(f * 1e6), round(f, 3)))
            rows.append((f"fig12.{mode}.w{wa}:{wb}.throughput_rps",
                         round(thr * 1e3), round(thr, 1)))
    return emit(rows)


if __name__ == "__main__":
    run_all()
