"""Prefill+decode serving benchmark: naive gather decode loop vs DecodeEngine.

Two ways to serve the same generative co-batch (batch × adapters × decode
steps grid):

  * ``gather_loop`` — the status-quo decode path before the engine existed:
    a jitted ``lm.decode_step`` per token with ``lora_impl="gather"`` (the
    (B, d, r) adapter weights are re-gathered every step), a bf16 KV cache,
    and a host round-trip (argmax on numpy logits) between every token.
  * ``engine`` — the ``DecodeEngine``: persistent int8 KV slot pool, SGMV
    segment metadata built once per batch composition, and chunked
    device-resident greedy decode (one dispatch + one host sync per chunk).

Reported per cell: decode ms/step for both paths and the speedup. The
steady-state section drives request churn (join/leave with changing adapter
assignments) through the engine and records that the jitted executable count
stays flat and the host-side segment sort runs only on composition changes —
the invariants the tests assert (``tests/test_decode_engine.py``).

Results land under the "decode" section of ``BENCH_serving.json``.
"""
from __future__ import annotations

import argparse
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from common import write_serving_section
from repro.configs import get_config, reduced
from repro.core.decode_engine import DecodeEngine
from repro.core.physical import PhysicalFM, slot_bucket_for
from repro.models import lm

BATCHES = (2, 4, 8, 16)
ADAPTERS = (2, 4, 8)
DECODE_STEPS = (16, 64)       # >= 2 decode chunks: steady state, not boundary
PROMPT_LEN = 16
WARMUP = 1
REPEATS = 5

_gather_jits: dict = {}        # (kind, batch) -> jitted fn, shared across cells


def _fm(cfg, num_adapters: int) -> PhysicalFM:
    fm = PhysicalFM(cfg, seed=0, input_len=PROMPT_LEN, lora_rank=8,
                    lora_impl="segmented", seg_block_t=16)
    for i in range(num_adapters):
        tree = fm.adapters._mod.init_single_adapter(
            jax.random.PRNGKey(i), fm.cfg, fm.adapters.rank)
        leaves, tdef = jax.tree.flatten(tree)
        ks = jax.random.split(jax.random.PRNGKey(1000 + i), len(leaves))
        fm.adapters.add(f"lora{i}", jax.tree.unflatten(tdef, [
            jax.random.normal(k, l.shape, l.dtype) * 0.05
            for k, l in zip(ks, leaves)]))
    return fm


def gather_decode_loop(fm: PhysicalFM, prompts: np.ndarray, aidx: np.ndarray,
                       steps: int):
    """Status-quo baseline: jitted per-token gather decode, bf16 KV, host
    argmax every token. Returns (ttft_s, decode_s, tokens)."""
    cfg = fm.cfg
    B = prompts.shape[0]
    s_max = prompts.shape[1] + steps + 1
    stack = fm.adapters.stacked()
    key = ("prefill", B, s_max)
    if key not in _gather_jits:
        def pre(params, toks, stack, ai):
            cache = lm.init_cache(cfg, B, s_max)
            return lm.prefill(params, cfg, tokens=toks, cache=cache,
                              lora=stack, adapter_idx=ai, lora_impl="gather")
        _gather_jits[key] = jax.jit(pre)
    key_d = ("decode", B)
    if key_d not in _gather_jits:
        def dec(params, tok, cache, stack, ai):
            return lm.decode_step(params, cfg, tokens=tok, cache=cache,
                                  lora=stack, adapter_idx=ai,
                                  lora_impl="gather")
        _gather_jits[key_d] = jax.jit(dec)
    ai = jnp.asarray(aidx)
    t0 = time.perf_counter()
    logits, cache = _gather_jits[key](fm.params, jnp.asarray(prompts), stack, ai)
    tok = np.argmax(np.asarray(logits), axis=-1).astype(np.int32)   # host sync
    jax.block_until_ready(cache)     # don't let async prefill leak into decode
    t1 = time.perf_counter()
    toks = [tok]
    for _ in range(steps - 1):
        logits, cache = _gather_jits[key_d](fm.params, jnp.asarray(tok), cache,
                                            stack, ai)
        tok = np.argmax(np.asarray(logits), axis=-1).astype(np.int32)
        toks.append(tok)
    return t1 - t0, time.perf_counter() - t1, np.stack(toks, axis=1)


def engine_decode(eng: DecodeEngine, prompts: np.ndarray, aidx_names, steps: int):
    """Engine path. Returns (ttft_s, decode_s, tokens)."""
    t0 = time.perf_counter()
    for i in range(prompts.shape[0]):
        eng.join(f"t{i}", prompts[i], adapter_id=aidx_names[i],
                 max_new_tokens=steps, rid=i)
    jax.block_until_ready(eng.pool)  # attribute async admission to TTFT,
    t1 = time.perf_counter()         # not to the first decode chunk
    done = sorted(eng.drain(), key=lambda s: s.rid)
    return t1 - t0, time.perf_counter() - t1, \
        np.asarray([d.tokens for d in done])


def run_all(out_path: str = None, smoke: bool = False):
    global BATCHES, ADAPTERS, DECODE_STEPS
    if smoke:
        BATCHES, ADAPTERS, DECODE_STEPS = (8,), (4,), (16,)
    repeats = 1 if smoke else REPEATS
    cfg = reduced(get_config("stablelm-1.6b"))
    fms = {}
    for na in ADAPTERS:
        cap = slot_bucket_for(na)
        if cap not in fms:
            fms[cap] = _fm(cfg, cap)
    engines = {}
    grid = []
    rng = np.random.RandomState(0)
    for b in BATCHES:
        prompts = rng.randint(0, cfg.vocab_size,
                              (b, PROMPT_LEN)).astype(np.int32)
        for na in ADAPTERS:
            cap = slot_bucket_for(na)
            fm = fms[cap]
            names = [f"lora{i % na}" for i in range(b)]
            aidx = np.asarray([fm.adapters.index(n) for n in names], np.int32)
            ekey = (b, cap)
            if ekey not in engines:
                engines[ekey] = DecodeEngine(
                    fm, num_slots=b, prompt_len=PROMPT_LEN,
                    max_new=max(DECODE_STEPS), chunk=8)
            eng = engines[ekey]
            for steps in DECODE_STEPS:
                g_ms, e_ms, ttft_g, ttft_e = [], [], [], []
                for it in range(WARMUP + repeats):
                    tg, dg, toks_g = gather_decode_loop(fm, prompts, aidx, steps)
                    te, de, toks_e = engine_decode(eng, prompts, names, steps)
                    if it >= WARMUP:
                        g_ms.append(dg * 1e3 / max(steps - 1, 1))
                        e_ms.append(de * 1e3 / max(steps - 1, 1))
                        ttft_g.append(tg * 1e3)
                        ttft_e.append(te * 1e3 / b)   # per-request admission
                row = {
                    "batch": b, "num_adapters": na, "decode_steps": steps,
                    "gather_loop_ms_per_step": round(statistics.median(g_ms), 3),
                    "engine_ms_per_step": round(statistics.median(e_ms), 3),
                    "gather_prefill_ms": round(statistics.median(ttft_g), 3),
                    "engine_admission_ms_per_req": round(
                        statistics.median(ttft_e), 3),
                    # int8-KV engine vs bf16-KV loop: tokens can diverge by
                    # quantization; report agreement, not strict equality
                    "token_agreement": round(
                        float((toks_g == toks_e).mean()), 3),
                }
                row["speedup"] = round(row["gather_loop_ms_per_step"] /
                                       max(row["engine_ms_per_step"], 1e-9), 2)
                grid.append(row)
                print(f"b={b:3d} na={na:2d} steps={steps:3d} "
                      f"gather={row['gather_loop_ms_per_step']:7.2f}ms/step "
                      f"engine={row['engine_ms_per_step']:7.2f}ms/step "
                      f"x{row['speedup']:.2f} agree={row['token_agreement']}")

    # steady state: request churn (join/leave, adapter reassignment) across
    # chunks must add zero executables and only re-sort on composition change
    fm = fms[min(fms)]
    eng = DecodeEngine(fm, num_slots=4, prompt_len=PROMPT_LEN, max_new=16,
                       chunk=4)
    prompts = rng.randint(0, cfg.vocab_size, (8, PROMPT_LEN)).astype(np.int32)
    for i in range(4):
        eng.join(f"t{i}", prompts[i], adapter_id=f"lora{i % 2}",
                 max_new_tokens=6 + i, rid=i)
    eng.drain()                                     # warm all executables
    compiles_before = eng.compile_count()
    builds_before = fm.seg_meta_cache.builds
    for i in range(4, 8):                           # churn: new compositions
        eng.join(f"t{i}", prompts[i], adapter_id=f"lora{(i + 1) % 2}",
                 max_new_tokens=5 + i % 3, rid=i)
    # steady segment: drain with stable composition; sorts only on the
    # occupancy changes caused by joins/retires, never per token
    eng.drain()
    steady = {
        "recompiles_after_churn": eng.compile_count() - compiles_before,
        "seg_meta_builds_during_churn": fm.seg_meta_cache.builds - builds_before,
        "decode_steps_executed": eng.steps,
        "jit_entries": len(eng._jit_decode) + len(eng._jit_prefill) + 1,
    }
    print("steady state:", steady)
    assert steady["recompiles_after_churn"] == 0, steady

    # the acceptance condition this PR is judged on: segmented engine decode
    # beats the naive gather loop wherever co-batching bites (b>=8, na>=4)
    target = [r for r in grid if r["batch"] >= 8 and r["num_adapters"] >= 4]
    wins = sum(1 for r in target if r["speedup"] > 1.0)
    print(f"engine beats gather loop in {wins}/{len(target)} cells "
          f"with batch >= 8, adapters >= 4")

    out = {
        "config": cfg.name,
        "prompt_len": PROMPT_LEN,
        "chunk": 8,
        "warmup": WARMUP,
        "repeats": repeats,
        "stat": "median",
        "grid": grid,
        "segmented_beats_gather_b8_na4": wins == len(target),
        "steady_state": steady,
    }
    write_serving_section("decode", out, out_path)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: single cell, 1 repeat")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    run_all(out_path=args.out, smoke=args.smoke)
