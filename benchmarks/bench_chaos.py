"""Chaos benchmark: the fault-tolerant serving plane under injected faults.

One mixed pooled + generative workload runs twice over identical traces:

  * ``baseline`` — no injected faults (deadline enforcement still active:
    the ~10% infeasible-deadline requests are shed in BOTH runs);
  * ``chaos``    — ``serving.faults.ChaosInjector`` arms, mid-run: a NaN'd
    LoRA adapter (one gen task's streams quarantine via the in-graph
    finite-logits flag), a raising task head (executor isolates it to that
    task's rows), page-allocator pressure (forced deferrals/preemptions
    through the admission gate), and a stalled engine (the loop watchdog
    trips and degrades gracefully).

Acceptance asserts, per ISSUE 6:
  * zero crashes / zero wedges — both runs terminate with EVERY trace
    request reaching a terminal state;
  * EXACT token parity — every clean stream (feasible deadline, unfaulted
    task) that completes in the chaos run produces token-for-token the same
    output as in the fault-free run (greedy rows are independent, so faults
    must not perturb co-batched streams at all);
  * zero steady-state recompiles — the whole chaos run (NaN adapter stack
    rebuild included) adds no jit keys after warmup;
  * clean-traffic goodput within 10% of baseline is RECORDED
    (``goodput_within_10pct``; soft on CPU, where wall-clock noise between
    two timed runs exceeds the bound).

Results land under the "chaos" section of ``BENCH_serving.json``.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from common import write_serving_section
from repro.configs import get_config, reduced
from repro.core.physical import PhysicalFM
from repro.core.request import SLO, Request
from repro.core.server import FMplexServer
from repro.core.vfm import TaskExtensions
from repro.serving.faults import (ChaosEvent, ChaosInjector, NaNAdapterFault,
                                  PagePressureFault, RaisingHeadFault,
                                  StallFault)
from repro.serving.loadgen import feature_trace, merge, token_trace
from repro.serving.metrics import failure_counters, mixed_stats

PROMPT_LEN = 16
MAX_NEW = 24
HORIZON = 2.0
GEN_RPS = 8.0                  # per clean gen task
CHAOS_RPS = 1.0                # NaN'd task: ~5% of the stream volume
POOLED_RPS = 30.0
INFEASIBLE_FRAC = 0.10
WATCHDOG_S = 0.12


def build(seed: int = 0):
    cfg = reduced(get_config("stablelm-1.6b"))
    fm = PhysicalFM(cfg, seed=seed, input_len=PROMPT_LEN, lora_rank=4)
    fm.calibrate(sizes=(1, 2, 4, 8))
    srv = FMplexServer("s0")
    srv.deploy_fm("fm0", fm, scheduler="bfq")
    rng = np.random.RandomState(seed)
    w = rng.randn(cfg.d_model, 4).astype(np.float32) * 0.1
    w2 = rng.randn(cfg.d_model, 4).astype(np.float32) * 0.1
    srv.bind_task("pooled", "fm0", weight=2.0,
                  extensions=TaskExtensions(decoder=lambda f: f @ w))
    # the head the chaos run crashes; its OWN requests fail, nobody else's
    srv.bind_task("badhead", "fm0", weight=1.0,
                  extensions=TaskExtensions(decoder=lambda f: f @ w2))
    for i, tid in enumerate(("gen0", "gen1", "chaosgen")):
        fm.adapters.new(f"lora{i}", seed=i)
        srv.bind_task(tid, "fm0", weight=1.0,
                      extensions=TaskExtensions(adapter_id=f"lora{i}"))
    srv.decode_engine("fm0", num_slots=4, prompt_len=PROMPT_LEN,
                      max_new=MAX_NEW, chunk=4, paged=True, page_size=8)
    loop = srv.serve_loop("fm0", watchdog_stall_s=WATCHDOG_S)
    return srv, cfg, loop


def build_trace(cfg):
    gen = merge([
        token_trace("gen0", GEN_RPS, HORIZON, prompt_len=PROMPT_LEN,
                    vocab=cfg.vocab_size, max_new=MAX_NEW, seed=1,
                    min_prompt_len=4, infeasible_frac=INFEASIBLE_FRAC),
        token_trace("gen1", GEN_RPS, HORIZON, prompt_len=PROMPT_LEN,
                    vocab=cfg.vocab_size, max_new=MAX_NEW, seed=2,
                    min_prompt_len=4, infeasible_frac=INFEASIBLE_FRAC),
        token_trace("chaosgen", CHAOS_RPS, HORIZON, prompt_len=PROMPT_LEN,
                    vocab=cfg.vocab_size, max_new=MAX_NEW, seed=3,
                    min_prompt_len=4),
    ])
    # a short Poisson horizon can sample ZERO chaosgen arrivals; the
    # quarantine assertions need the NaN'd task present deterministically
    rng = np.random.RandomState(99)
    gen += [Request("chaosgen", HORIZON * f,
                    payload=rng.randint(0, cfg.vocab_size,
                                        PROMPT_LEN).astype("int32"),
                    tokens=float(PROMPT_LEN + 4), max_new_tokens=4)
            for f in (0.1, 0.35)]
    pooled = merge([
        feature_trace("pooled", POOLED_RPS, HORIZON, input_len=PROMPT_LEN,
                      d_model=cfg.d_model, seed=4),
        feature_trace("badhead", POOLED_RPS / 3.0, HORIZON,
                      input_len=PROMPT_LEN, d_model=cfg.d_model, seed=5),
    ])
    return merge([gen, pooled])


def chaos_events():
    return [
        # poisoned adapter for the whole run: every chaosgen stream must
        # quarantine, no clean stream may notice
        ChaosEvent(at=0.0, fault=NaNAdapterFault("lora2")),
        # head crash for the first 60%: later badhead requests recover
        ChaosEvent(at=0.05, fault=RaisingHeadFault("badhead"),
                   duration=HORIZON * 0.6),
        # page famine mid-run: deferrals/preemptions, never a wedge
        ChaosEvent(at=HORIZON * 0.25, fault=PagePressureFault(0.6),
                   duration=HORIZON * 0.2),
        # stalled engine long enough for >= 1 watchdog trip
        ChaosEvent(at=HORIZON * 0.55, fault=StallFault(),
                   duration=max(3.0 * WATCHDOG_S, HORIZON * 0.15)),
    ]


def _clone(r: Request) -> Request:
    return Request(r.task_id, r.arrival, payload=r.payload, tokens=r.tokens,
                   max_new_tokens=r.max_new_tokens,
                   slo=SLO(r.slo.deadline_s))


def run_once(loop, trace, max_wall, injector=None):
    clones = [_clone(r) for r in trace]
    keymap = {c.rid: i for i, c in enumerate(clones)}
    t0 = time.perf_counter()
    served = loop.run(clones, max_wall=max_wall,
                      on_tick=injector.on_tick if injector else None)
    wall = time.perf_counter() - t0
    if injector is not None:
        injector.restore_all(loop)
    return {keymap[r.rid]: r for r in served if r.rid in keymap}, wall


def run_all(out_path: str = None, smoke: bool = False):
    global HORIZON, GEN_RPS, POOLED_RPS
    if smoke:
        HORIZON, GEN_RPS, POOLED_RPS = 0.8, 6.0, 20.0
    srv, cfg, loop = build()
    eng = srv.decode_engine("fm0")
    fm = srv.fms["fm0"]
    ex = srv.executors["fm0"]
    max_wall = 60.0 if smoke else 300.0

    loop.warmup(pooled_task="pooled", gen_task="gen0", pooled_n=8)
    compiles = eng.compile_count() + fm.compile_count()

    trace = build_trace(cfg)
    gen_idx = {i for i, r in enumerate(trace) if r.max_new_tokens > 0}
    infeasible = {i for i in gen_idx
                  if trace[i].slo.deadline_s is not None
                  and trace[i].slo.deadline_s < 1e-3}
    clean = {i for i in gen_idx - infeasible
             if trace[i].task_id in ("gen0", "gen1")}

    def fresh_sched():
        srv.deploy_fm("fm0", profile=srv.profiles["fm0"], scheduler="bfq")

    fresh_sched()
    base, base_wall = run_once(loop, trace, max_wall)

    fresh_sched()
    loop.failures.clear()
    injector = ChaosInjector(chaos_events())
    chaos, chaos_wall = run_once(loop, trace, max_wall, injector=injector)
    fails = failure_counters(chaos.values(), loop=loop, engine=eng,
                             executor=ex)
    recompiles = eng.compile_count() + fm.compile_count() - compiles

    # -- zero wedges / zero crashes: every request reached a terminal state
    # in both runs and the engine fully drained
    assert len(base) == len(trace), \
        f"baseline dropped requests: {len(base)}/{len(trace)}"
    assert len(chaos) == len(trace), \
        f"chaos run dropped requests: {len(chaos)}/{len(trace)}"
    for i, r in chaos.items():
        assert r.finish_time is not None, f"non-terminal request {i}"
    assert eng.active_count() == 0 and eng.pending_count() == 0, \
        "engine did not drain"

    # -- the chaos run actually exercised every fault path
    assert fails["quarantined"] > 0, "NaN adapter produced no quarantines"
    assert fails["head_failed"] > 0, "raising head produced no failures"
    assert fails["watchdog_trips"] > 0, "stall produced no watchdog trip"
    assert fails["deadline_shed"] + fails["deadline_cancelled"] > 0, \
        "infeasible deadlines produced no shedding"
    # every chaosgen stream that ran is quarantined, never 'ok'
    for i, r in chaos.items():
        if trace[i].task_id == "chaosgen":
            assert r.status != "ok", f"NaN'd stream {i} completed ok"

    # -- EXACT token parity: clean streams completing in both runs emit
    # identical tokens (greedy rows are independent — faults in co-batched
    # streams must not perturb them)
    compared = mismatched = 0
    for i in clean:
        rb, rc = base.get(i), chaos.get(i)
        if rb is None or rc is None or rb.status != "ok" \
                or rc.status != "ok":
            continue
        compared += 1
        if not np.array_equal(np.asarray(rb.result), np.asarray(rc.result)):
            mismatched += 1
    assert compared > 0, "no clean streams completed in both runs"
    assert mismatched == 0, \
        f"{mismatched}/{compared} clean streams lost token parity"

    # -- goodput for clean traffic, chaos vs baseline (recorded; soft)
    def clean_goodput(res, wall):
        toks = sum(len(r.result) for i, r in res.items()
                   if i in clean and r.status == "ok"
                   and r.result is not None)
        return toks / max(wall, 1e-9)

    g_base = clean_goodput(base, base_wall)
    g_chaos = clean_goodput(chaos, chaos_wall)
    ratio = g_chaos / max(g_base, 1e-9)

    ms = mixed_stats([r for r in chaos.values()],
                     page_samples=loop.page_samples,
                     shared_samples=loop.shared_samples, failures=fails)
    out = {
        "config": cfg.name,
        "horizon_s": HORIZON,
        "trace_len": len(trace),
        "clean_streams": len(clean),
        "infeasible_deadline_frac": INFEASIBLE_FRAC,
        "chaos_events": [(t, name, act) for t, name, act in injector.log],
        "baseline": {"served": len(base),
                     "clean_goodput_tokens_per_s": round(g_base, 2)},
        "chaos": {"served": len(chaos),
                  "clean_goodput_tokens_per_s": round(g_chaos, 2),
                  "stats": ms},
        "failures": fails,
        "parity": {"compared": compared, "mismatched": mismatched},
        "clean_goodput_ratio": round(ratio, 4),
        "goodput_within_10pct": bool(ratio >= 0.9),
        "steady_state_recompiles_chaos": recompiles,
    }
    print(f"served: base={len(base)}/{len(trace)} "
          f"chaos={len(chaos)}/{len(trace)}")
    print(f"failures: { {k: v for k, v in fails.items() if v} }")
    print(f"parity: {compared} clean streams compared, "
          f"{mismatched} mismatched")
    print(f"clean goodput: base={g_base:.1f} tok/s chaos={g_chaos:.1f} "
          f"tok/s (x{ratio:.2f}, within 10%: {ratio >= 0.9})")
    print(f"steady-state recompiles across chaos: {recompiles}")
    assert recompiles == 0, "chaos run must not add jit keys"
    write_serving_section("chaos", out, out_path)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: short horizon, lighter rates")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    run_all(out_path=args.out, smoke=args.smoke)
