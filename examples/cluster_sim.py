"""Cluster-scale scenario: Max-Share placement of 60 tasks over 8 servers,
a demand surge handled by vFM rebinding, and a server failure handled by
Controller-driven recovery — all over the discrete-event simulator.

  PYTHONPATH=src python examples/cluster_sim.py
"""
from repro.controller import (ClusterState, ElasticAdapter, MaxShare, Server,
                              TaskSpec)
from repro.controller.profiles import get_profile


def main():
    profiles = {b: get_profile(b) for b in
                ("moment-large", "dinov2-base", "qwen2.5-3b")}
    cluster = ClusterState([Server(f"s{i}") for i in range(8)], profiles)
    ms = MaxShare(cluster)

    backbones = ["moment-large"] * 3 + ["dinov2-base"] * 2 + ["qwen2.5-3b"]
    placed = 0
    for i in range(60):
        t = TaskSpec(f"t{i}", backbones[i % len(backbones)], demand_rps=2.0)
        if ms.place(t):
            placed += 1
    print(f"placed {placed}/60 tasks on {len(cluster.deployments)} shared "
          f"deployments across {len(cluster.servers)} servers "
          f"(instance-per-task would need {placed} deployments)")

    ea = ElasticAdapter(cluster)
    r = ea.on_surge(TaskSpec("t0", "moment-large", demand_rps=2.0), 30.0)
    print(f"surge on t0 -> {r.path} (capacity ready in {r.ready_s*1e3:.0f} ms, "
          f"routed over {len(r.assignment)} deployment(s))")

    victim = next(iter(cluster.deployments.values())).server_id
    moved = ea.on_server_failure(victim)
    rebinds = sum(1 for m in moved if m.path == "rebind")
    print(f"server {victim} failed -> {len(moved)} tasks recovered "
          f"({rebinds} cheap rebinds, {len(moved)-rebinds} provisions)")


if __name__ == "__main__":
    main()
