"""End-to-end driver (the paper's kind is SERVING): boot a real FMplex server
with one shared JAX backbone and several vFMs (LoRA adapters + decoder heads),
replay batched Poisson traffic through BFQ, and report latency + fairness.

Three workload planes:

  * pooled features (default) — every request is one shared forward; per-task
    decoder heads run on-device over the pooled features;
  * generative decode (``--decode``) — requests carry prompts + token budgets
    and stream through the continuous-batching ``DecodeEngine``: admission
    prefill into a persistent int8 KV slot pool, then chunked segmented-LoRA
    greedy decode with requests joining/leaving slots between chunks. Reports
    token-level metrics (TTFT / TPOT / tokens-per-second);
  * mixed (``--mixed``) — pooled AND generative traffic through ONE event
    loop (``ServeLoop``): each tick BFQ picks the smallest-virtual-tag unit
    of work — a pooled sub-batch, a variable-length prefill admission, or a
    decode chunk — so pooled batches interleave between chunks and streams
    join the pool mid-flight. Reports both planes side by side.

``--paged`` switches the decode pool to the block-paged int8 KV layout
(pages allocated on demand, recycled at retire, memory-aware admission that
defers instead of crashing on bursts) and reports the free/used page gauges.

  PYTHONPATH=src python examples/serve_multitask.py --tasks 4 --rps 40 --seconds 8
  PYTHONPATH=src python examples/serve_multitask.py --decode --tasks 4 --rps 10
  PYTHONPATH=src python examples/serve_multitask.py --decode --paged --tasks 4 --rps 10
  PYTHONPATH=src python examples/serve_multitask.py --mixed --paged --tasks 4 --rps 30
  PYTHONPATH=src python examples/serve_multitask.py --chaos --paged --tasks 4 --rps 30

``--chaos`` runs the mixed plane with ``serving.faults`` armed (a NaN'd
adapter, a raising head, an engine stall, infeasible deadlines) and reports
the failure-plane counters — every fault lands as a terminal request status,
never a crash.
"""
import argparse

import numpy as np

from repro.launch.serve import build_server, run_load
from repro.serving.metrics import decode_stats, jain_fairness, latency_stats


def pooled_main(args):
    for sched in ("bfq", "stfq", "s-be"):
        srv, cfg = build_server(args.tasks, scheduler=sched,
                                weights=[1.0 + i for i in range(args.tasks)])
        reqs = run_load(srv, cfg, rps=args.rps, seconds=args.seconds,
                        n_tasks=args.tasks)
        done = [r for r in reqs if r.finish_time is not None]
        s = latency_stats(done)
        shares = {t: sum(1 for r in done if r.task_id == t)
                  for t in srv.vfms}
        weights = {t: srv.vfms[t].weight for t in srv.vfms}
        print(f"{sched:>5s}: served {s['n']:4d} mean={s['mean_ms']:7.1f}ms "
              f"p99={s['p99_ms']:8.1f}ms "
              f"fairness={jain_fairness(shares, weights):.3f}")


def decode_main(args):
    """Generative serving demo on a decoder LM backbone: token-level traffic
    through the DecodeEngine, scheduled by BFQ like any other request."""
    import time

    from repro.core.request import Request
    from repro.serving.loadgen import merge, token_trace

    srv, cfg = build_server(args.tasks, arch="stablelm-1.6b",
                            input_len=args.prompt_len, scheduler="bfq",
                            slo_s=None)   # cold compiles inside measured loop
    eng = srv.decode_engine("fm0", num_slots=8, prompt_len=args.prompt_len,
                            max_new=args.max_new, chunk=4,
                            **_paged_kwargs(args))
    traces = merge([token_trace(f"task{i}", args.rps / args.tasks,
                                args.seconds, prompt_len=args.prompt_len,
                                vocab=cfg.vocab_size, max_new=args.max_new,
                                seed=i) for i in range(args.tasks)])
    t0 = time.perf_counter()
    served = []
    for r in traces:
        # replay with arrivals rebased to wall clock; the synchronous loop
        # admits whatever has arrived, then serves one BFQ batch
        now = time.perf_counter()
        srv.on_arrival(Request(r.task_id, now, payload=r.payload,
                               tokens=r.tokens,
                               max_new_tokens=r.max_new_tokens), now)
        batch = srv.step("fm0")
        if batch is not None:
            served += batch.requests
    while (batch := srv.step("fm0")) is not None:
        served += batch.requests          # drain the queued tail too
    served = [r for r in served if r.finish_time is not None]
    s = decode_stats(served)
    print(f"decode: served {s['n']} requests, {s['tokens_out']} tokens "
          f"({s['tokens_per_s']:.1f} tok/s) "
          f"ttft p50={s['ttft_p50_ms']:.1f}ms p99={s['ttft_p99_ms']:.1f}ms "
          f"tpot p50={s['tpot_p50_ms']:.2f}ms")
    print(f"engine: {eng.steps} decode steps, "
          f"{eng.compile_count()} jitted executables (flat under churn), "
          f"{srv.fms['fm0'].seg_meta_cache.builds} host-side segment sorts")
    if args.paged:
        from repro.serving.metrics import page_gauges
        print(f"kv pages: {page_gauges(eng)}")


def mixed_main(args):
    """Pooled + generative colocation through one event loop: half the tasks
    send pooled feature bursts, half stream variable-length prompts with
    token budgets; BFQ interleaves both planes at token granularity."""
    from repro.serving.loadgen import feature_trace, merge, token_trace
    from repro.serving.metrics import mixed_stats

    srv, cfg = build_server(args.tasks, arch="stablelm-1.6b",
                            input_len=args.prompt_len, scheduler="bfq",
                            slo_s=None)   # --chaos demos deadline enforcement
    eng = srv.decode_engine("fm0", num_slots=8, prompt_len=args.prompt_len,
                            max_new=args.max_new, chunk=4,
                            **_paged_kwargs(args))
    loop = srv.serve_loop("fm0")
    n_gen = max(1, args.tasks // 2)
    # warm the executables so the measured run reflects steady state
    loop.warmup(pooled_task=f"task{args.tasks - 1}", gen_task="task0")
    loop.ticks.clear()
    traces = [feature_trace(f"task{i}", args.rps / args.tasks, args.seconds,
                            input_len=args.prompt_len, d_model=cfg.d_model,
                            seed=i) for i in range(n_gen, args.tasks)]
    traces += [token_trace(f"task{i}", args.rps / args.tasks / 4,
                           args.seconds, prompt_len=args.prompt_len,
                           min_prompt_len=2, vocab=cfg.vocab_size,
                           max_new=args.max_new, seed=i)
               for i in range(n_gen)]
    served = loop.run(merge(traces))
    s = mixed_stats(served, page_samples=loop.page_samples,
                    shared_samples=loop.shared_samples)
    eng = srv.engines["fm0"]
    print(f"mixed: {len(served)} served, ticks={dict(loop.ticks)}")
    p, d = s["pooled"], s["decode"]
    if p.get("n"):
        print(f"  pooled: n={p['n']} p50={p['p50_ms']:.1f}ms "
              f"p99={p['p99_ms']:.1f}ms")
    if d.get("n"):
        print(f"  decode: n={d['n']} {d['tokens_out']} tokens "
              f"({d['tokens_per_s']:.1f} tok/s) "
              f"ttft p50={d['ttft_p50_ms']:.1f}ms "
              f"tpot p50={d['tpot_p50_ms']:.2f}ms")
    print(f"  engine: buckets={eng.prompt_buckets}, {eng.steps} decode "
          f"steps, {eng.compile_count()} jitted executables (flat under "
          f"churn), {srv.fms['fm0'].seg_meta_cache.builds} host-side sorts")
    if args.paged:
        from repro.serving.metrics import page_gauges
        kv = s.get("kv_pages", {})
        sh = s.get("kv_sharing", {})
        print(f"  kv pages: occupancy p50={kv.get('occupancy_p50')} "
              f"p95={kv.get('occupancy_p95')} dedup "
              f"p50={sh.get('dedup_frac_p50')} | {page_gauges(eng)}")


def chaos_main(args):
    """Fault-tolerant serving demo: the mixed event-loop workload with the
    chaos harness armed — one task's adapter NaN'd (its streams quarantine,
    co-batched streams unaffected), one task's head raising (only its rows
    fail), a tenth of the generative requests carrying infeasible deadlines
    (shed before they cost a prefill), and a mid-run engine stall the loop
    watchdog recovers from. Prints the failure-plane counters next to the
    usual serving stats."""
    from repro.serving.faults import (ChaosEvent, ChaosInjector,
                                      NaNAdapterFault, RaisingHeadFault,
                                      StallFault)
    from repro.serving.loadgen import feature_trace, merge, token_trace
    from repro.serving.metrics import failure_counters, mixed_stats

    srv, cfg = build_server(max(args.tasks, 3), arch="stablelm-1.6b",
                            input_len=args.prompt_len, scheduler="bfq",
                            slo_s=None)
    n_tasks = max(args.tasks, 3)
    eng = srv.decode_engine("fm0", num_slots=8, prompt_len=args.prompt_len,
                            max_new=args.max_new, chunk=4,
                            **_paged_kwargs(args))
    loop = srv.serve_loop("fm0", watchdog_stall_s=0.25)
    loop.warmup(pooled_task=f"task{n_tasks - 1}", gen_task="task0")
    loop.ticks.clear()
    loop.failures.clear()
    # task0 streams get the NaN'd adapter; task{n-1}'s head raises;
    # the rest is clean traffic with 10% infeasible deadlines
    traces = [token_trace(f"task{i}", args.rps / n_tasks / 4, args.seconds,
                          prompt_len=args.prompt_len, min_prompt_len=2,
                          vocab=cfg.vocab_size, max_new=args.max_new,
                          seed=i, infeasible_frac=0.1)
              for i in range(max(1, n_tasks // 2))]
    traces += [feature_trace(f"task{i}", args.rps / n_tasks, args.seconds,
                             input_len=args.prompt_len, d_model=cfg.d_model,
                             seed=i) for i in range(n_tasks // 2, n_tasks)]
    injector = ChaosInjector([
        ChaosEvent(at=0.0, fault=NaNAdapterFault("lora0")),
        ChaosEvent(at=args.seconds * 0.1,
                   fault=RaisingHeadFault(f"task{n_tasks - 1}"),
                   duration=args.seconds * 0.5),
        ChaosEvent(at=args.seconds * 0.5, fault=StallFault(),
                   duration=1.0),
    ])
    served = loop.run(merge(traces), on_tick=injector.on_tick)
    injector.restore_all(loop)
    fails = failure_counters(served, loop=loop, engine=eng,
                             executor=srv.executors["fm0"])
    s = mixed_stats(served, page_samples=loop.page_samples,
                    shared_samples=loop.shared_samples, failures=fails)
    p, d = s["pooled"], s["decode"]
    print(f"chaos: {len(served)} served, ticks={dict(loop.ticks)}")
    print(f"  chaos events: {injector.log}")
    print(f"  failures: { {k: v for k, v in fails.items() if v} }")
    if p.get("n"):
        print(f"  pooled (ok): n={p['n']} p50={p['p50_ms']:.1f}ms "
              f"p99={p['p99_ms']:.1f}ms")
    if d.get("n"):
        print(f"  decode (ok): n={d['n']} failed={d['n_failed']} "
              f"{d['tokens_out']} tokens ({d['tokens_per_s']:.1f} tok/s, "
              f"goodput {d['goodput_tokens_per_s']:.1f} tok/s)")
    print(f"  engine: {eng.steps} decode steps, {eng.compile_count()} "
          f"jitted executables (flat under chaos)")


def _paged_kwargs(args) -> dict:
    if not args.paged:
        return {}
    kw = dict(paged=True, page_size=args.page_size,
              prefix_sharing=not args.no_prefix_sharing)
    if args.total_pages:
        kw["total_pages"] = args.total_pages
    return kw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tasks", type=int, default=4)
    ap.add_argument("--rps", type=float, default=40.0)
    ap.add_argument("--seconds", type=float, default=8.0)
    ap.add_argument("--decode", action="store_true",
                    help="generative serving via the DecodeEngine")
    ap.add_argument("--mixed", action="store_true",
                    help="pooled + generative traffic through one event loop")
    ap.add_argument("--chaos", action="store_true",
                    help="mixed traffic with the chaos-injection harness "
                         "armed (NaN adapter, raising head, engine stall)")
    ap.add_argument("--paged", action="store_true",
                    help="block-paged int8 KV pool (pages on demand, "
                         "memory-aware admission) instead of dense slots")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--total-pages", type=int, default=0,
                    help="KV arena size in pages (default: dense-equivalent)")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable copy-on-write prompt-prefix page sharing")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    if args.chaos:
        chaos_main(args)
    elif args.mixed:
        mixed_main(args)
    elif args.decode:
        decode_main(args)
    else:
        pooled_main(args)


if __name__ == "__main__":
    main()
