"""End-to-end driver (the paper's kind is SERVING): boot a real FMplex server
with one shared JAX backbone and several vFMs (LoRA adapters + decoder heads),
replay batched Poisson traffic through BFQ, and report latency + fairness.

  PYTHONPATH=src python examples/serve_multitask.py --tasks 4 --rps 40 --seconds 8
"""
import argparse

from repro.launch.serve import build_server, run_load
from repro.serving.metrics import jain_fairness, latency_stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tasks", type=int, default=4)
    ap.add_argument("--rps", type=float, default=40.0)
    ap.add_argument("--seconds", type=float, default=8.0)
    args = ap.parse_args()

    for sched in ("bfq", "stfq", "s-be"):
        srv, cfg = build_server(args.tasks, scheduler=sched,
                                weights=[1.0 + i for i in range(args.tasks)])
        reqs = run_load(srv, cfg, rps=args.rps, seconds=args.seconds,
                        n_tasks=args.tasks)
        done = [r for r in reqs if r.finish_time is not None]
        s = latency_stats(done)
        shares = {t: sum(1 for r in done if r.task_id == t)
                  for t in srv.vfms}
        weights = {t: srv.vfms[t].weight for t in srv.vfms}
        print(f"{sched:>5s}: served {s['n']:4d} mean={s['mean_ms']:7.1f}ms "
              f"p99={s['p99_ms']:8.1f}ms "
              f"fairness={jain_fairness(shares, weights):.3f}")


if __name__ == "__main__":
    main()
