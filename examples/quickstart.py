"""Quickstart: build, fine-tune and package an FMplex task pipeline
(paper Listing 1/2) against a MOMENT-style backbone.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs import get_config, reduced
from repro.taskapi import (Adapter, LinearChannelCombiner, MLPDecoder,
                           Pipeline, vFM)
from repro.taskapi.artifacts import serialize, task_spec


def main():
    # 1. a vFM handle over the backbone (reduced config for CPU)
    cfg = reduced(get_config("moment-large"))
    P = Pipeline(vFM(cfg), task_id="heart_rate")

    # 2. compose the task pipeline (paper Listing 1)
    P.add_encoder(LinearChannelCombiner(num_channels=3, new_num_channels=1,
                                        patch=8, d_model=cfg.d_model))
    P.add_decoder(MLPDecoder(input_dim=cfg.d_model, hidden_dim=64, output_dim=1))
    P.attach_adapter(Adapter(rank=4, adapter_id="hr_lora"))

    # 3. fine-tune extensions; the shared backbone stays frozen (Listing 2)
    rng = np.random.RandomState(0)

    def data():
        while True:
            x = rng.randn(16, 64, 3).astype(np.float32)   # (B, T, channels)
            y = (x[:, :, 0].mean(axis=1) * 5.0 + 1.0)[:, None]
            yield x, y

    losses = P.train(data(), steps=100, lr=5e-3, loss="mse", verbose=True)
    print(f"loss: {losses[0]:.4f} -> {min(losses[-10:]):.4f}")

    # 4. inference through the pipeline
    y = P.run(rng.randn(4, 64, 3).astype(np.float32))
    print("predictions:", np.asarray(y).ravel())

    # 5. package as a deployment artifact for FMplex-Controller
    art = P.package(weight=2.0, slo_s=0.2, demand_rps=5.0)
    blob = serialize(art)
    print(f"artifact: {len(blob)} bytes, spec={task_spec(art)}")


if __name__ == "__main__":
    main()
