"""End-to-end fault-tolerant training on a ~125M-class architecture
(xlstm-125m reduced for CPU; pass --full-width for the real width at short
depth). Demonstrates checkpoint/restart, failure injection and straggler
detection from repro.launch.train.

  PYTHONPATH=src python examples/train_e2e.py --steps 60
"""
import argparse
import dataclasses
import tempfile

import numpy as np

from repro.configs import get_config, reduced
from repro.distributed.fault import FailureInjector
from repro.launch.train import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--fail-at", type=int, default=25,
                    help="inject a node failure at this step (-1 = off)")
    ap.add_argument("--full-width", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.full_width:
        cfg = dataclasses.replace(cfg, num_layers=4)   # full width, short depth
    else:
        cfg = reduced(cfg)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        tr = Trainer(cfg, batch=args.batch, seq=args.seq, ckpt_dir=ckpt_dir,
                     ckpt_every=10, lr=1e-3, total_steps=args.steps)
        inj = FailureInjector(args.fail_at if args.fail_at >= 0 else None)
        losses = tr.run(args.steps, injector=inj)
        print(f"arch={cfg.name} steps={len(losses)} "
              f"loss {losses[0]:.3f} -> {np.mean(losses[-5:]):.3f} "
              f"(restarted={inj.fired}, stragglers={len(tr.straggler.events)})")
        assert np.mean(losses[-5:]) < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
